"""Partitioned executor ≡ dense engine (bit-identical), partitioner arrays
invariants, exchange accounting, and the distribution-aware cost model.

Equivalence tests are thin wrappers over the shared four-way differential
harness in ``conformance.py`` (which also runs its own generated matrix in
``test_conformance.py``)."""
import numpy as np
import pytest

import conformance as C
from repro.core import engine as E
from repro.core import engine_partitioned as EP
from repro.graphdata.partitioner import (build_partition_arrays,
                                         partition_graph)
from repro.graphdata.queries import make_workload

ALL_MODES = (E.MODE_STATIC, E.MODE_BUCKET, E.MODE_INTERVAL)
WORKERS = (2, 4, 8)


# ---------------------------------------------------------------- arrays
def _arrays(graph, w):
    return build_partition_arrays(
        graph, partition_graph(graph, n_workers=w, parts_per_type=4))


def test_partition_arrays_cover_exactly_once(medium_static_graph):
    g = medium_static_graph
    for w in WORKERS:
        pa = _arrays(g, w)
        own = pa.own_ids[pa.own_ids < g.n_vertices]
        assert own.shape[0] == g.n_vertices
        assert np.array_equal(np.sort(own), np.arange(g.n_vertices))
        eids = pa.edge_ids[pa.edge_ids < 2 * g.n_edges]
        assert np.array_equal(np.sort(eids), np.arange(2 * g.n_edges))


def test_partition_arrays_edges_follow_arrival_owner(medium_static_graph):
    g = medium_static_graph
    pa = _arrays(g, 4)
    t_dst = g.traversal["t_dst"]
    t_src = g.traversal["t_src"]
    for w in range(4):
        eids = pa.edge_ids[w][pa.edge_ids[w] < 2 * g.n_edges]
        # every owned edge arrives at a vertex this worker owns ...
        assert (pa.owner_of_vertex[t_dst[eids]] == w).all()
        # ... in canonical (arrival-sorted) order
        assert np.array_equal(eids, np.sort(eids))
        # halo covers exactly the sources of the owned edges
        halo = pa.halo_ids[w][: pa.n_halo[w]]
        assert set(t_src[eids]) == set(halo.tolist())


def test_partition_arrays_balanced_and_deterministic(medium_static_graph):
    g = medium_static_graph
    pa1 = _arrays(g, 4)
    pa2 = _arrays(g, 4)
    assert np.array_equal(pa1.own_ids, pa2.own_ids)
    assert np.array_equal(pa1.edge_ids, pa2.edge_ids)
    # round-robin typed sub-partitions keep owned-vertex counts balanced
    assert pa1.n_own.max() <= 2.0 * max(pa1.n_own.mean(), 1)
    assert pa1.exchange_volume() == int(pa1.n_ghost.sum()) > 0


# ---------------------------------------------------------------- parity
def test_partitioned_equals_dense_all_modes(small_dynamic_graph):
    """Acceptance: bit-identical results for the LDBC workload templates,
    all modes × n_workers ∈ {2,4,8} (thin wrapper over conformance)."""
    g = small_dynamic_graph
    wl = make_workload(g, n_per_template=1, seed=33)
    nonzero = 0
    for inst in wl:
        for mode in ALL_MODES:
            legs = C.engine_results(g, inst.qry, mode, workers=WORKERS,
                                    n_buckets=8)
            C.assert_engines_identical(legs, (inst.template, mode))
            nonzero += float(np.sum(legs["dense"]["total"])) > 0
    assert nonzero >= 5  # the workload must actually exercise matches


def test_partitioned_all_splits(small_static_graph):
    g = small_static_graph
    inst = make_workload(g, templates=("Q4",), n_per_template=1, seed=7)[0]
    for split in range(inst.qry.n_vertices):
        legs = C.engine_results(g, inst.qry, E.MODE_STATIC, workers=(4,),
                                split=split)
        C.assert_engines_identical(legs, ("Q4", split))


def test_partitioned_count_aggregate(small_static_graph):
    g = small_static_graph
    inst = make_workload(g, templates=("Q2",), n_per_template=1, seed=5,
                         aggregate=True)[0]
    legs = C.engine_results(g, inst.qry, E.MODE_STATIC, workers=(4,))
    C.assert_engines_identical(legs, "Q2-agg")


def test_partitioned_minmax_aggregate(small_static_graph):
    """MIN/MAX aggregates run partitioned, bit-identical to dense AND to the
    oracle (static mode; thin wrapper over conformance)."""
    from repro.core import query as Q
    from repro.core.ref_engine import RefEngine
    g = small_static_graph
    b = g.meta["builder"]
    oracle = RefEngine(g)
    for op in (Q.AGG_MIN, Q.AGG_MAX):
        qry = Q.PathQuery(
            v_preds=(Q.VertexPredicate(b.v_type_ids["person"]),
                     Q.VertexPredicate(b.v_type_ids["post"])),
            e_preds=(Q.EdgePredicate(b.e_type_ids["created"], Q.DIR_OUT),),
            agg_op=op, agg_key=b.key_ids["length"],
        )
        for mode in ALL_MODES:
            legs = C.engine_results(g, qry, mode, workers=WORKERS)
            C.assert_engines_identical(legs, ("minmax", op, mode))
            if mode == E.MODE_STATIC:
                C.assert_oracle_aggregate(oracle, g, qry, mode, legs)


# ------------------------------------------------------------ instrumented
def test_measure_supersteps_matches_dense(small_static_graph):
    g = small_static_graph
    inst = make_workload(g, templates=("Q2",), n_per_template=1, seed=31)[0]
    prof = EP.measure_supersteps(g, inst.qry, n_workers=4, repeats=1)
    want = E.count_results(g, inst.qry, sliced=False)
    assert prof.total == want
    n_hops = len(inst.qry.e_preds)
    assert prof.times_s.shape == (n_hops, 4)
    assert (prof.times_s > 0).all()          # measured, not modelled
    assert prof.makespan_s.shape == (n_hops,)
    assert 0 < prof.balance_eff <= 1.0
    assert (prof.exchange_msgs >= 0).all()


def test_etr_exchange_scales_with_cut(small_static_graph):
    """Acceptance: the ETR-hop exchange volume reported by measure_supersteps
    is the boundary rank-summary count (cut segments' summaries), NOT the
    full per-edge frontier the first implementation reassembled."""
    g = small_static_graph
    _, arrays, _ = EP.partition_for(g, 4, None)
    frontier = 2 * g.n_edges
    cut = arrays.etr_exchange_volume()
    assert 0 < cut < frontier
    inst = make_workload(g, templates=("Q4",), n_per_template=1, seed=7)[0]
    prof = EP.measure_supersteps(g, inst.qry, n_workers=4, repeats=1)
    assert prof.total == E.count_results(g, inst.qry, sliced=False)
    etr_hops = [i for i, ep in enumerate(inst.qry.e_preds)
                if ep.etr_op != -1]
    assert etr_hops, "Q4 must carry ETR hops"
    for i, ep in enumerate(inst.qry.e_preds):
        if i in etr_hops:
            assert prof.exchange_msgs[i] == cut      # summaries for cut edges
            assert prof.exchange_msgs[i] < frontier  # … not the frontier
        else:
            assert prof.exchange_msgs[i] == arrays.exchange_volume()


# ------------------------------------------------------- empty-ghost pads
def _two_type_graph():
    """Type-1 vertices have no edges at all, so with one sub-partition per
    worker some workers own empty edge/halo/ghost sets — the regression
    surface for the src_halo pad sentinel."""
    from repro.core.graph import TemporalGraph
    n0, n1 = 8, 4
    V = n0 + n1
    v_type = np.asarray([0] * n0 + [1] * n1, np.int32)
    v_life = np.tile(np.asarray([[0, 100]], np.int32), (V, 1))
    e_src = np.asarray([0, 1, 2, 3, 4, 5, 6, 7, 0, 2], np.int32)
    e_dst = np.asarray([1, 2, 3, 4, 5, 6, 7, 0, 4, 6], np.int32)
    e_type = np.zeros(len(e_src), np.int32)
    e_life = np.tile(np.asarray([[10, 90]], np.int32), (len(e_src), 1))
    return TemporalGraph(v_type, v_life, e_src, e_dst, e_type, e_life,
                         vprops={}, eprops={}, n_vertex_types=2,
                         n_edge_types=1, lifespan=(0, 100))


def test_empty_ghost_partition_pads_cannot_alias(small_static_graph):
    """src_halo pads index the per-worker sentinel slot (= Hmax), never halo
    slot 0 — which aliases a real vertex whenever a halo is non-empty and is
    plain wrong when a worker's ghost/halo set is empty."""
    from repro.core import query as Q
    g = _two_type_graph()
    pa = build_partition_arrays(
        g, partition_graph(g, n_workers=8, parts_per_type=4))
    assert (pa.n_halo == 0).any(), "precondition: some worker has no halo"
    for w in range(pa.n_workers):
        pads = pa.src_halo[w, pa.n_edges[w]:]
        assert (pads == pa.h_max).all(), w
        # real entries stay in range
        assert (pa.src_halo[w, : pa.n_edges[w]] < pa.n_halo[w]).all(), w
    # executor parity on the graph with empty-halo workers (all modes)
    qry = Q.PathQuery(
        v_preds=(Q.VertexPredicate(0), Q.VertexPredicate(0),
                 Q.VertexPredicate(0)),
        e_preds=(Q.EdgePredicate(0, Q.DIR_OUT), Q.EdgePredicate(0, Q.DIR_OUT)),
    )
    for mode in ALL_MODES:
        legs = C.engine_results(g, qry, mode, workers=(8,), n_buckets=4)
        C.assert_engines_identical(legs, ("empty-ghost", mode))
        assert float(np.sum(legs["dense"]["total"])) > 0
    # the LDBC fixture keeps exercising the non-empty-halo path
    pa2 = build_partition_arrays(
        small_static_graph, partition_graph(small_static_graph, n_workers=4,
                                            parts_per_type=4))
    for w in range(4):
        assert (pa2.src_halo[w, pa2.n_edges[w]:] == pa2.h_max).all()


# ------------------------------------------------------------- shard_map
def test_partitioned_shard_map_multi_device():
    """The worker axis lowers to a real device mesh (4 forced host devices)."""
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax
assert jax.device_count() == 4
from repro.core import engine as E
from repro.core import engine_partitioned as EP
from repro.core import query as Q
from repro.graphdata.ldbc import LdbcParams, generate_ldbc
from repro.graphdata.queries import make_workload
g = generate_ldbc(LdbcParams(n_persons=40, seed=5, dynamic=True))
inst = make_workload(g, templates=("Q2",), n_per_template=1, seed=33)[0]
for mode in (E.MODE_STATIC, E.MODE_BUCKET):
    want = np.asarray(E.execute(g, inst.qry, mode=mode, n_buckets=8,
                                sliced=False).total)
    got = np.asarray(EP.execute(g, inst.qry, mode=mode, n_buckets=8,
                                n_workers=4, use_shard_map=True).total)
    assert np.array_equal(got, want), (mode, got, want)
# ETR hop: the rank-summary exchange lowers under shard_map too
etr = make_workload(g, templates=("Q8",), n_per_template=1, seed=33)[0]
want = np.asarray(E.execute(g, etr.qry, mode=E.MODE_STATIC,
                            sliced=False).total)
got = np.asarray(EP.execute(g, etr.qry, mode=E.MODE_STATIC, n_workers=4,
                            use_shard_map=True).total)
assert np.array_equal(got, want), ("etr", got, want)
# MIN/MAX: extremum publish combines with pmin/pmax across devices
b = g.meta["builder"]
qmm = Q.PathQuery(
    v_preds=(Q.VertexPredicate(b.v_type_ids["person"]),
             Q.VertexPredicate(b.v_type_ids["post"])),
    e_preds=(Q.EdgePredicate(b.e_type_ids["created"], Q.DIR_OUT),),
    agg_op=Q.AGG_MIN, agg_key=b.key_ids["length"])
dense = E.execute(g, qmm, sliced=False)
part = EP.execute(g, qmm, n_workers=4, use_shard_map=True)
assert np.array_equal(np.asarray(dense.minmax), np.asarray(part.minmax))
assert np.array_equal(np.asarray(dense.per_vertex), np.asarray(part.per_vertex))
print("PARTITIONED_SHARD_MAP_OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PARTITIONED_SHARD_MAP_OK" in out.stdout, out.stderr[-2000:]


# ------------------------------------------------------------- cost model
def test_planner_distribution_aware(medium_static_graph):
    """With a partitioning, plans pay a θ_net exchange term scaled by the
    partitioner's cut; distributed estimates stay finite and ordered; every
    query class (incl. MIN/MAX and ETR hops) is costed on the distributed
    path — no dense-only fallback in plan selection."""
    import dataclasses
    from repro.core import query as Q
    from repro.core.planner import Planner
    from repro.core.stats import GraphStats

    g = medium_static_graph
    stats = GraphStats(g, n_time_buckets=16)
    part = partition_graph(g, n_workers=4, parts_per_type=4)
    coeffs = dict(theta0=0.1, theta_v=1e-5, theta_e=1e-5, theta_etr=1e-5,
                  theta_m=1e-5, theta_init=1e-5, theta_net=1e-4)
    single = Planner(g, stats, coeffs=coeffs)
    multi = Planner(g, stats, coeffs=coeffs, partitioning=part)
    assert multi.n_workers == 4 and 0.0 < multi.cut_frac < 1.0
    # structural exchange volumes in the executor's units: halo ghosts on
    # plain hops, boundary rank summaries (cut edges, < frontier) on ETR hops
    assert 0 < multi.exchange_volume
    assert 0 < multi.etr_exchange_volume < 2 * g.n_edges
    wl = make_workload(g, templates=("Q2", "Q4"), n_per_template=1, seed=3)
    for inst in wl:
        for split in single.enumerate_plans(inst.qry):
            e1 = single.estimate(inst.qry, split)
            e4 = multi.estimate(inst.qry, split)
            assert np.isfinite(e4.t_ms) and e4.t_ms > 0
            # exchange volume recorded on the distributed steps only
            assert all(s.m_net == 0.0 for s in e1.steps)
            # ETR steps pay the cut-summary volume, never the frontier;
            # plain hops pay the halo-ghost volume
            for s in e4.steps:
                if s.etr:
                    assert s.m_net == multi.etr_exchange_volume
                else:
                    assert s.m_net in (0.0, multi.exchange_volume)
        # the distributed planner still returns a valid best plan
        best = multi.choose(inst.qry)
        assert best.split in single.enumerate_plans(inst.qry)
    # MIN/MAX gets a distributed plan too: extremum channel rides the
    # exchange, so its hops cost MORE than the plain-count plan's
    qry = wl[0].qry
    qmm = dataclasses.replace(qry, agg_op=Q.AGG_MIN, agg_key=0)
    est_cnt = multi.estimate(dataclasses.replace(qry, agg_op=Q.AGG_COUNT,
                                                 agg_key=0), 0)
    est_mm = multi.estimate(qmm, 0)
    assert np.isfinite(est_mm.t_ms) and est_mm.t_ms > est_cnt.t_ms
