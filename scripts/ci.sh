#!/usr/bin/env bash
# One-command CI gate: tier-1 tests + heavy legs selected BY MARKER + bench
# regression gate.
#   ./scripts/ci.sh            # full gate
#   ./scripts/ci.sh --fast     # tier-1 only (every-push leg)
#
# Heavy legs (full gate only):
#   kernels      the kernel-layer equivalence leg (`-m kernels`): fused hop
#                kernel vs the XLA hop across modes × aggregates, layout
#                property tests
#   serving      the SLO serving layer (`-m serving`): deadline EDF,
#                admission control, online θ refit, and both replay modes on
#                the FakeDispatcher virtual clock (tier-1 also runs these;
#                the dedicated leg keeps the SLO surface visible in the gate)
#   obs          the query flight recorder (`-m obs`): span trees pinned on
#                the virtual clock, metrics exposition, the cost-model audit
#                replayed from trace JSONL, traced-vs-untraced bit-identity
#   ingest       live-graph serving (`-m ingest`): event-log validation,
#                incremental-vs-from-scratch materialization identity,
#                replay order-insensitivity, delta execution, epoch-pinned
#                cache metrics, and the conformance ingestion leg
#   fault        fault tolerance (`-m fault`): deterministic chaos injection,
#                retry bit-identity, deadline-aware retry budgets, poison
#                quarantine bisection, worker-loss dense fallback, and WAL
#                torn-tail crash recovery
#   docs         scripts/check_docs.py: every fenced command in README.md +
#                docs/*.md parses, the cheap ```bash run blocks execute,
#                and every file:line anchor points at a real line
#   conformance  the four-way differential matrix at CONFORMANCE_SCALE=ci
#                (full worker sweep + all ETR operators + the pallas impl
#                axis), selected with `-m conformance` — tier-1 already runs
#                it at smoke scale
#   multidevice  shard_map-native batched serving on 8 forced host devices
#                (XLA_FLAGS), bit-identity vs the vmap simulation
#   smokes       engine-vs-oracle and workload/scheduler sweeps
#   benches      serving replay + weak scaling, producing BENCH_*.json,
#                then scripts/check_bench.py diffs them against the
#                committed baselines (benchmarks/baselines/) and FAILS on
#                regression beyond the tolerance band
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export BENCH_SCALE="${BENCH_SCALE:-ci}"

echo "== tier-1: pytest (markers 'slow'/'multidevice' deselected by pytest.ini) =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
  echo "== kernels: fused hop kernel vs XLA hop equivalence (-m kernels) =="
  python -m pytest -m kernels -x -q
  echo "== serving SLO: deadlines/EDF, admission, online refit, replay (-m serving) =="
  python -m pytest -m serving -x -q
  echo "== obs: flight recorder spans, metrics, cost-model audit (-m obs) =="
  python -m pytest -m obs -x -q
  echo "== ingest: live-graph serving — event log, epochs, delta exec (-m ingest) =="
  python -m pytest -m ingest -x -q
  echo "== fault: chaos injection, retry/quarantine, worker loss, WAL recovery (-m fault) =="
  python -m pytest -m fault -x -q
  echo "== docs: fenced commands + file:line anchors (scripts/check_docs.py) =="
  python scripts/check_docs.py
  echo "== conformance: four-way differential matrix at CI scale (-m conformance) =="
  CONFORMANCE_SCALE=ci python -m pytest -m conformance -x -q
  echo "== multidevice: shard_map serving vs vmap simulation on 8 forced devices =="
  XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -m multidevice -x -q
  echo "== smoke: engine vs oracle (all modes/splits) =="
  python scripts/smoke_engine.py
  echo "== smoke: workload + batched scheduler =="
  python scripts/smoke_workload.py
  echo "== serving: LDBC replay through the batch scheduler (artifact: BENCH_serving.json) =="
  BENCH_ENFORCE=1 python -m benchmarks.serving
  echo "== weak scaling: measured partitioned supersteps (artifact: BENCH_weak_scaling.json) =="
  python -m benchmarks.weak_scaling
  echo "== bench gate: BENCH_*.json vs committed baselines =="
  python scripts/check_bench.py
fi

echo "CI GATE PASSED"
