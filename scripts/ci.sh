#!/usr/bin/env bash
# One-command CI gate: tier-1 tests + conformance matrix + engine smoke at
# CI scale.
#   ./scripts/ci.sh            # full gate
#   ./scripts/ci.sh --fast     # tests only (skip conformance matrix + smoke)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export BENCH_SCALE="${BENCH_SCALE:-ci}"

echo "== tier-1: pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
  echo "== conformance: four-way differential matrix at CI scale =="
  CONFORMANCE_SCALE=ci python -m pytest tests/test_conformance.py -x -q
  echo "== smoke: engine vs oracle (all modes/splits) =="
  python scripts/smoke_engine.py
  echo "== smoke: workload + batched scheduler =="
  python scripts/smoke_workload.py
  echo "== serving: LDBC replay through the batch scheduler (artifact: BENCH_serving.json) =="
  BENCH_ENFORCE=1 python -m benchmarks.serving
fi

echo "CI GATE PASSED"
