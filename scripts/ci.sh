#!/usr/bin/env bash
# One-command CI gate: tier-1 tests + engine smoke at CI scale.
#   ./scripts/ci.sh            # full gate
#   ./scripts/ci.sh --fast     # tests only (skip the smoke oracle sweep)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export BENCH_SCALE="${BENCH_SCALE:-ci}"

echo "== tier-1: pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
  echo "== smoke: engine vs oracle (all modes/splits) =="
  python scripts/smoke_engine.py
fi

echo "CI GATE PASSED"
