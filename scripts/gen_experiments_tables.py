"""Generate the EXPERIMENTS.md §Dry-run / §Roofline / §Perf tables from the
artifacts in experiments/dryrun and experiments/perf."""
import glob
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def load(d):
    out = []
    for f in sorted(glob.glob(os.path.join(ROOT, "experiments", d, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        r["_file"] = os.path.basename(f)
        out.append(r)
    return out


def ft(t):
    if t is None:
        return "—"
    if t >= 1.0:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t*1e3:.2f}ms"
    return f"{t*1e6:.1f}µs"


def fb(b):
    if not b:
        return "—"
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    if b >= 1e6:
        return f"{b/1e6:.1f}MB"
    return f"{b/1e3:.0f}kB"


ORDER = ["llama3-405b", "minicpm-2b", "gemma3-4b", "olmoe-1b-7b",
         "mixtral-8x22b", "pna", "egnn", "meshgraphnet", "schnet",
         "dlrm-rm2", "granite-ldbc"]


def dryrun_table(mesh):
    recs = [r for r in load("dryrun") if r.get("mesh") == mesh]
    recs.sort(key=lambda r: (ORDER.index(r["arch"]) if r["arch"] in ORDER else 99,
                             r["shape"]))
    rows = ["| arch | shape | status | per-dev args | per-dev temp | "
            "HLO GFLOPs/dev | HLO GB/dev | coll GB/dev | compile s |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped | "
                        f"{r['reason']} | | | | | |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        m = r.get("memory_per_device") or {}
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {fb(m.get('argument_bytes'))} "
            f"| {fb(m.get('temp_bytes'))} | {r['hlo_flops']/1e9:.1f} "
            f"| {r['hlo_bytes']/1e9:.2f} | {r['collective_bytes']/1e9:.2f} "
            f"| {r.get('t_compile_s', 0):.0f} |")
    return "\n".join(rows)


def roofline_table(mesh="single"):
    recs = [r for r in load("dryrun") if r.get("mesh") == mesh]
    recs.sort(key=lambda r: (ORDER.index(r["arch"]) if r["arch"] in ORDER else 99,
                             r["shape"]))
    rows = ["| arch | shape | t_compute | t_memory | t_collective | bottleneck "
            "| useful FLOPs (6·N·D / HLO) | scan scale |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"*skipped: {r['reason']}* | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        uf = r.get("useful_flops_frac")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {ft(r['t_compute'])} "
            f"| {ft(r['t_memory'])} | {ft(r['t_collective'])} "
            f"| **{r['bottleneck']}** "
            f"| {'%.0f%%' % (uf*100) if uf else '—'} "
            f"| {r.get('scan_scale', 1):.1f} |")
    return "\n".join(rows)


def perf_table():
    recs = load("perf")
    rows = ["| cell | iteration | t_compute | t_memory | t_collective | "
            "bottleneck | per-dev temp | per-dev args |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        m = r.get("memory_per_device") or {}
        cell, it = r["arch"].rsplit("__", 1)
        rows.append(
            f"| {cell} | {it} | {ft(r['t_compute'])} | {ft(r['t_memory'])} "
            f"| {ft(r['t_collective'])} | {r['bottleneck']} "
            f"| {fb(m.get('temp_bytes'))} | {fb(m.get('argument_bytes'))} |")
    return "\n".join(rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("## single-pod (16×16 = 256 chips)\n")
        print(dryrun_table("single"))
        print("\n## multi-pod (2×16×16 = 512 chips)\n")
        print(dryrun_table("multi"))
    if which in ("all", "roofline"):
        print("\n## roofline (single-pod)\n")
        print(roofline_table())
    if which in ("all", "perf"):
        print("\n## perf iterations\n")
        print(perf_table())
