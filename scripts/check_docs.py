#!/usr/bin/env python
"""Docs gate: every fenced command in README.md and docs/*.md must at least
parse, the cheap ones must RUN, and every ``file:line`` anchor must point at
a real line — so the documentation cannot silently rot as the code moves
(scripts/ci.sh runs this as the ``docs`` leg).

Three checks:

  syntax   every ```bash fenced block goes through ``bash -n`` — a typo'd
           flag continuation or unbalanced quote fails CI even when the
           command is too expensive to execute;
  run      blocks fenced as ```bash run additionally EXECUTE (bash -e,
           repo root, PYTHONPATH=src) with a per-block timeout — the
           convention marks the cheap, side-effect-free examples; anything
           heavy (benches, the full CI gate) stays syntax-checked only;
  anchors  every ``path/to/file.py:123`` reference must name an existing
           repo file with at least that many lines.  Anchors are how
           docs/architecture.md's lifecycle walkthrough stays honest: move
           the code without updating the doc and this gate fails.

Exit non-zero on any failure; `--list` prints what would be checked.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUN_TIMEOUT_S = 300

FENCE_RE = re.compile(r"^```bash([ \t]+run)?[ \t]*\n(.*?)^```",
                      re.MULTILINE | re.DOTALL)
# path:line anchors: a repo-relative path ending in a known source suffix,
# a colon, and a line number.  (Plain prose colons never match — the path
# must contain a slash or be a top-level file with a source suffix.)
ANCHOR_RE = re.compile(
    r"`([A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:py|sh|md|ini|toml|json)):(\d+)`")


def doc_files() -> list:
    out = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        out += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                      if f.endswith(".md"))
    return out


def check_blocks(path: str, execute: bool) -> list:
    failures = []
    with open(path) as f:
        text = f.read()
    rel = os.path.relpath(path, REPO)
    for i, m in enumerate(FENCE_RE.finditer(text)):
        tag_run, body = bool(m.group(1)), m.group(2)
        line = text[:m.start()].count("\n") + 1
        tag = f"{rel}:{line} block#{i}"
        syn = subprocess.run(["bash", "-n"], input=body, text=True,
                             capture_output=True)
        if syn.returncode != 0:
            failures.append((tag, "syntax", syn.stderr.strip()))
            print(f"  [FAIL] {tag}: bash -n: {syn.stderr.strip()}")
            continue
        if tag_run and execute:
            env = dict(os.environ)
            env["PYTHONPATH"] = ("src" + os.pathsep + env["PYTHONPATH"]
                                 if env.get("PYTHONPATH") else "src")
            try:
                run = subprocess.run(["bash", "-e"], input=body, text=True,
                                     capture_output=True, cwd=REPO, env=env,
                                     timeout=RUN_TIMEOUT_S)
            except subprocess.TimeoutExpired:
                failures.append((tag, "run", f"timeout {RUN_TIMEOUT_S}s"))
                print(f"  [FAIL] {tag}: run timed out")
                continue
            if run.returncode != 0:
                tail = (run.stderr or run.stdout).strip().splitlines()[-5:]
                failures.append((tag, "run", "; ".join(tail)))
                print(f"  [FAIL] {tag}: exit {run.returncode}: "
                      + " | ".join(tail))
            else:
                print(f"  [ok  ] {tag}: ran ({len(body.splitlines())} lines)")
        else:
            kind = "syntax-only (heavy)" if tag_run and not execute \
                else "syntax"
            print(f"  [ok  ] {tag}: {kind}")
    return failures


def check_anchors(path: str) -> list:
    failures = []
    with open(path) as f:
        text = f.read()
    rel = os.path.relpath(path, REPO)
    for m in ANCHOR_RE.finditer(text):
        target, line_no = m.group(1), int(m.group(2))
        tag = f"{rel}: `{target}:{line_no}`"
        full = os.path.join(REPO, target)
        if not os.path.isfile(full):
            failures.append((tag, "anchor", "file does not exist"))
            print(f"  [FAIL] {tag}: file does not exist")
            continue
        with open(full) as f:
            n_lines = sum(1 for _ in f)
        if line_no < 1 or line_no > n_lines:
            failures.append((tag, "anchor",
                             f"line {line_no} > {n_lines} lines"))
            print(f"  [FAIL] {tag}: line {line_no} out of range "
                  f"(file has {n_lines})")
        else:
            print(f"  [ok  ] {tag}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-run", action="store_true",
                    help="syntax-check the ```bash run blocks instead of "
                    "executing them")
    ap.add_argument("--list", action="store_true",
                    help="print the files that would be checked and exit")
    args = ap.parse_args()
    files = doc_files()
    if args.list:
        for f in files:
            print(os.path.relpath(f, REPO))
        return 0
    failures = []
    for f in files:
        print(f"{os.path.relpath(f, REPO)}:")
        failures += check_blocks(f, execute=not args.no_run)
        failures += check_anchors(f)
    if failures:
        print(f"\nDOCS GATE FAILED: {len(failures)} problem(s)")
        for tag, kind, msg in failures:
            print(f"  - {tag} [{kind}]: {msg}")
        return 1
    print("\nDOCS GATE PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
