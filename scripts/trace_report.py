#!/usr/bin/env python
"""Render a query flight-recorder trace (JSONL from ``--trace-out`` or
``benchmarks/serving.py``) as per-query text waterfalls plus a workload
rollup, and optionally the cost-model audit.

    python scripts/trace_report.py BENCH_serving_trace.jsonl
    python scripts/trace_report.py trace.jsonl --limit 5 --audit

Waterfall: one indented line per span, with its duration bar positioned
inside the root span's window and its headline attrs.  Rollup: per-template
counts and predicted-vs-measured dispatch error, admission verdicts, hop
exchange volumes per channel, and — when the run was not clean — a failures
section (rejected/quarantined/timed-out queries with their structured
errors, plus injected-fault action counts).  ``--audit`` appends
obs/audit.audit_report
(telemetry replay, coefficient drift, plan-accuracy metric).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import Counter, defaultdict

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs import audit  # noqa: E402
from repro.obs.trace import load_jsonl, span_trees  # noqa: E402

BAR_W = 32

#: headline attrs per span kind (everything else stays in the JSONL)
_HEADLINE = {
    "query": ("template", "status", "latency_ms"),
    "admit": ("verdict", "rungs"),
    "plan": ("split", "impl", "plan_cached", "predicted_ms"),
    "compile": ("cache", "key"),
    "dispatch": ("seq", "batch", "edf_pos", "predicted_ms", "measured_ms"),
    "superstep": ("hop", "etr", "predicted_ms", "measured_ms"),
    "exchange": ("state", "extremum", "etr"),
    "measure_supersteps": ("n_workers", "n_hops", "impl"),
}


def _fmt_val(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    if isinstance(v, list):
        return ",".join(str(x) for x in v) or "-"
    return str(v)


def _bar(t0: float, t1: float, lo: float, span: float) -> str:
    if span <= 0:
        return "[" + "#" * BAR_W + "]"
    a = int((t0 - lo) / span * BAR_W)
    b = max(int((t1 - lo) / span * BAR_W), a + 1)
    a, b = min(a, BAR_W - 1), min(b, BAR_W)
    return "[" + " " * a + "#" * (b - a) + " " * (BAR_W - b) + "]"


def _walk(rec: dict, depth: int, lo: float, span: float, out: list):
    attrs = rec.get("attrs", {})
    heads = _HEADLINE.get(rec["name"], ())
    shown = " ".join(f"{k}={_fmt_val(attrs[k])}" for k in heads
                     if k in attrs and attrs[k] is not None)
    t0, t1 = rec["t_start"], rec.get("t_end") or rec["t_start"]
    out.append(f"  {_bar(t0, t1, lo, span)} {'  ' * depth}"
               f"{rec['name']:<12s} {shown}")
    for child in rec.get("children", []):
        _walk(child, depth + 1, lo, span, out)


def waterfall(root: dict) -> str:
    lo = root["t_start"]
    hi = root.get("t_end") or lo
    stack, recs = [root], []
    while stack:
        rec = stack.pop()
        recs.append(rec)
        stack.extend(rec.get("children", []))
    hi = max([hi] + [r.get("t_end") or lo for r in recs])
    lines = [f"trace {root['trace_id']} "
             f"({root['attrs'].get('template', '?')}, "
             f"{(hi - lo) * 1e3:.3f} ms window)"]
    _walk(root, 0, lo, hi - lo, lines)
    return "\n".join(lines)


def rollup(records: list) -> str:
    lines = ["== workload rollup =="]
    rows = audit.query_summaries(records)
    by_template = defaultdict(list)
    verdicts = Counter()
    for row in rows:
        by_template[row["template"]].append(row)
        if row["verdict"]:
            verdicts[row["verdict"]] += 1
    lines.append(f"queries: {len(rows)}   spans: {len(records)}   "
                 f"group dispatches: {len(audit.dispatch_records(records))}")
    if verdicts:
        lines.append("admission: " + "  ".join(
            f"{k}={v}" for k, v in sorted(verdicts.items())))
    lines.append(f"{'template':<12s} {'n':>4s} {'done':>5s} "
                 f"{'pred ms':>10s} {'meas ms':>10s} {'abs rel err':>12s}")
    for t in sorted(by_template):
        rws = by_template[t]
        done = [r for r in rws if r["status"] == "done"
                and r["predicted_ms"] is not None]
        if done:
            pred = sum(r["predicted_ms"] for r in done) / len(done)
            meas = sum(r["measured_ms"] for r in done) / len(done)
            errs = [abs(r["predicted_ms"] - r["measured_ms"])
                    / max(abs(r["measured_ms"]), 1e-9) for r in done]
            err = sum(errs) / len(errs)
            lines.append(f"{t:<12s} {len(rws):>4d} {len(done):>5d} "
                         f"{pred:>10.4g} {meas:>10.4g} {err:>12.4g}")
        else:
            lines.append(f"{t:<12s} {len(rws):>4d} {0:>5d} "
                         f"{'-':>10s} {'-':>10s} {'-':>12s}")
    chan = Counter()
    for rec in records:
        if rec["name"] == "exchange":
            for ch in ("state", "extremum", "etr"):
                chan[ch] += rec["attrs"].get(ch, 0) or 0
    lines.append("exchange volume: " + "  ".join(
        f"{ch}={int(chan[ch])}" for ch in ("state", "extremum", "etr")))
    return "\n".join(lines)


def failures(records: list, sample: int = 5) -> str:
    """Rollup of non-done terminal statuses plus injected-fault actions.

    Queries that were rejected at admission, quarantined as poison, or timed
    out on their retry budget each leave a root 'query' span with a non-done
    status and a structured error; fault-injection/retry decisions leave
    parentless 'fault' spans (point, action).  Empty when the run was clean.
    """
    roots = span_trees(records)
    bad = [r for r in sorted(roots.values(), key=lambda r: r["t_start"])
           if r["name"] == "query"
           and r["attrs"].get("status", "done") != "done"]
    actions = Counter()
    for rec in records:
        if rec["name"] == "fault":
            a = rec["attrs"]
            actions[(a.get("point", "?"), a.get("action", "?"))] += 1
    if not bad and not actions:
        return ""
    lines = ["== failures =="]
    by_status = Counter(r["attrs"]["status"] for r in bad)
    lines.append("terminal: " + ("  ".join(
        f"{k}={v}" for k, v in sorted(by_status.items())) or "none"))
    if actions:
        lines.append("fault actions: " + "  ".join(
            f"{pt}/{ac}={n}" for (pt, ac), n in sorted(actions.items())))
    for r in bad[:sample]:
        a = r["attrs"]
        lines.append(f"  {a.get('template', '?'):<12s} "
                     f"{a['status']:<12s} {a.get('error', '')}")
    if len(bad) > sample:
        lines.append(f"  ... and {len(bad) - sample} more")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="trace JSONL path")
    ap.add_argument("--limit", type=int, default=3,
                    help="waterfalls to print (0 = none, -1 = all)")
    ap.add_argument("--audit", action="store_true",
                    help="append the cost-model audit report")
    ap.add_argument("--within", type=float, default=0.10,
                    help="--audit plan-accuracy tolerance (default 10%%)")
    args = ap.parse_args()

    records = load_jsonl(args.trace)
    if not records:
        print("empty trace")
        return 1
    roots = span_trees(records)
    queries = [roots[t] for t in sorted(roots)
               if roots[t]["name"] in ("query", "measure_supersteps")]
    n = len(queries) if args.limit < 0 else min(args.limit, len(queries))
    for root in queries[:n]:
        print(waterfall(root))
        print()
    print(rollup(records))
    fail = failures(records)
    if fail:
        print()
        print(fail)
    if args.audit:
        print("\n== cost-model audit ==")
        rep = audit.audit_report(records, within=args.within)
        print(json.dumps(rep, indent=2, default=str))
    return 0


if __name__ == "__main__":
    sys.exit(main())
