#!/usr/bin/env python
"""Bench-regression gate: diff freshly produced BENCH_*.json artifacts
against the committed baselines (benchmarks/baselines/) with a per-metric
tolerance band, and exit non-zero on regression — the CI perf trajectory
lock (scripts/ci.sh runs this after the serving + weak-scaling benches).

Tolerance design: wall-clock numbers vary with the host, so the gate pins

  * STRUCTURAL metrics exactly (point-to-point exchange volumes per channel,
    dispatch counts): same seeds → same graph → same partition → same lane
    content; any drift means the executor's boundary traffic changed;
  * RATIO metrics (batched-vs-sequential throughput, weak-scaling and
    balance efficiency, completion rates) within a generous multiplicative
    band — host-speed cancels in a ratio, so a real regression (a serialized
    batch path, a broken exchange) shows as a large drop while scheduler
    jitter does not.

Refresh the baselines intentionally (never implicitly) with --refresh after
a reviewed perf change:  python scripts/check_bench.py --refresh
"""
from __future__ import annotations

import argparse
import json
import os
import re
import shutil
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(REPO, "benchmarks", "baselines")

# (artifact, dotted path, kind, tolerance)
#   min_frac  fresh >= tol * baseline   (ratios/efficiencies: gate the drop)
#   max_rise  fresh <= tol * baseline   (costs: gate the rise)
#   exact     fresh == baseline         (structural invariants)
#   max_abs   fresh <= tol              (absolute, baseline-free: overhead
#                                        ratios whose acceptable value is a
#                                        constant, not a host-dependent one)
CHECKS = [
    # ---- serving: the batching win and its distributed leg.  The ratio
    # bands are wide (0.5) because the sequential denominator swings with
    # host load; benchmarks/serving.py separately enforces the ABSOLUTE >=2x
    # batched-vs-sequential floor via BENCH_ENFORCE, so the gate here only
    # has to catch collapses (a serialized batch path), not jitter.
    ("BENCH_serving.json", "throughput_ratio", "min_frac", 0.50),
    ("BENCH_serving.json", "dynamic_leg.throughput_ratio", "min_frac", 0.50),
    ("BENCH_serving.json", "sequential.completion_rate", "min_frac", 0.95),
    ("BENCH_serving.json", "replay.completion_rate", "min_frac", 0.95),
    ("BENCH_serving.json", "batched.n_dispatches", "exact", 0),
    ("BENCH_serving.json", "partitioned.throughput_vs_sequential",
     "min_frac", 0.50),
    ("BENCH_serving.json", "partitioned.n_dispatches", "exact", 0),
    ("BENCH_serving.json", "partitioned.exchange_volumes.state", "exact", 0),
    ("BENCH_serving.json", "partitioned.exchange_volumes.extremum",
     "exact", 0),
    ("BENCH_serving.json", "partitioned.exchange_volumes.etr", "exact", 0),
    ("BENCH_serving.json", "partitioned.exchange_per_superstep.state",
     "exact", 0),
    ("BENCH_serving.json", "partitioned.exchange_per_superstep.etr",
     "exact", 0),
    # ---- SLO layer: online refit, deadline admission, bounded closed loop.
    # benchmarks/serving.py separately enforces the ABSOLUTE acceptance
    # (admitted p99 <= deadline < plain p99, reject_rate > 0) via
    # BENCH_ENFORCE; the gate pins the ratios so the layer cannot silently
    # decay.  The closed-loop counters are structural (wave composition is
    # deterministic given the seeded workload) and pinned exactly.
    ("BENCH_serving.json", "slo.refit.improvement", "min_frac", 0.30),
    ("BENCH_serving.json", "slo.refit.online_tail_err", "max_rise", 2.50),
    ("BENCH_serving.json", "slo.overload.admitted_hit_rate",
     "min_frac", 0.80),
    ("BENCH_serving.json", "slo.overload.divergence", "min_frac", 0.40),
    ("BENCH_serving.json", "slo.overload.reject_rate", "max_rise", 1.30),
    ("BENCH_serving.json", "slo.closed.max_outstanding", "exact", 0),
    ("BENCH_serving.json", "slo.closed.max_batch", "exact", 0),
    ("BENCH_serving.json", "slo.closed.n_dispatches", "exact", 0),
    ("BENCH_serving.json", "slo.closed.completion_rate", "min_frac", 0.95),
    # ---- observability: the flight recorder must stay off the hot path.
    # Both are absolute gates (the acceptable ceiling is a constant): traced
    # dispatch time within 5% of untraced, and the disabled NullTracer
    # path's analytic bound within 1%.  Bit-identity of traced results is
    # asserted inside benchmarks/serving.py itself.
    ("BENCH_serving.json", "obs.traced_overhead", "max_abs", 1.05),
    ("BENCH_serving.json", "obs.null_overhead", "max_abs", 1.01),
    # ---- live-graph serving: epoch-pinned drains while ingesting.  The
    # structural counters (epochs, compactions, delta dispatches, the
    # bit-identity flag) are deterministic given the seeds and pinned
    # exactly; the latency ratio is an absolute ceiling (its acceptable
    # value is a constant — BENCH_ENFORCE inside benchmarks/serving.py
    # applies the same 3x floor).
    ("BENCH_serving.json", "ingest.latency_ratio", "max_abs", 3.0),
    ("BENCH_serving.json", "ingest.frozen_identical", "exact", 0),
    ("BENCH_serving.json", "ingest.n_epochs", "exact", 0),
    ("BENCH_serving.json", "ingest.n_compactions", "exact", 0),
    ("BENCH_serving.json", "ingest.delta_exec_dispatches", "exact", 0),
    ("BENCH_serving.json", "ingest.completion_rate", "min_frac", 0.95),
    # ---- fault tolerance: the chaos leg's completion contract is EXACT
    # (benchmarks/serving.py asserts it via BENCH_ENFORCE too — the gate
    # here keeps the counters from drifting: same seeded FaultPlan → same
    # consultations → same retry/quarantine/fallback counts).  Goodput vs
    # fault-free is a ratio band; recovery identity and the recovered WAL
    # shape are structural.
    ("BENCH_serving.json", "chaos.completion_rate", "min_frac", 1.0),
    ("BENCH_serving.json", "chaos.answers_identical", "exact", 0),
    ("BENCH_serving.json", "chaos.n_retries", "exact", 0),
    ("BENCH_serving.json", "chaos.n_quarantined", "exact", 0),
    ("BENCH_serving.json", "chaos.n_fallbacks", "exact", 0),
    ("BENCH_serving.json", "chaos.n_timeout", "exact", 0),
    ("BENCH_serving.json", "chaos.partitioned_restored", "exact", 0),
    ("BENCH_serving.json", "chaos.goodput_ratio", "min_frac", 0.50),
    ("BENCH_serving.json", "chaos.recovery.recovery_identical", "exact", 0),
    ("BENCH_serving.json", "chaos.recovery.n_recovered_epochs", "exact", 0),
    ("BENCH_serving.json", "chaos.recovery.n_open_survivors", "exact", 0),
    # ---- fused hop kernel vs materialize+segment_sum: the per-impl hop
    # timings.  Structural edge counts exact (same seed → same graph); the
    # speedup ratios in a band (benchmarks/serving.py separately enforces
    # the ABSOLUTE >1x floor via BENCH_ENFORCE, so the gate only catches a
    # collapse of the kernel path, not host jitter).
    ("BENCH_serving.json", "hop_delivery.static.edges", "exact", 0),
    ("BENCH_serving.json", "hop_delivery.static.speedup", "min_frac", 0.50),
    ("BENCH_serving.json", "hop_delivery.bucket.speedup", "min_frac", 0.50),
    # ---- weak scaling: efficiency band + structural exchange per row
    ("BENCH_weak_scaling.json", "rows[*].balance_eff", "min_frac", 0.70),
    ("BENCH_weak_scaling.json", "rows[*].weak_eff", "min_frac", 0.55),
    ("BENCH_weak_scaling.json", "rows[*].edge_cut", "max_rise", 1.15),
    ("BENCH_weak_scaling.json", "rows[*].exchange_volume", "exact", 0),
    ("BENCH_weak_scaling.json", "rows[*].etr_exchange_volume", "exact", 0),
    ("BENCH_weak_scaling.json", "rows[*].exchange_per_query.state",
     "exact", 0),
    ("BENCH_weak_scaling.json", "rows[*].exchange_per_query.extremum",
     "exact", 0),
    ("BENCH_weak_scaling.json", "rows[*].exchange_per_query.etr", "exact", 0),
    ("BENCH_weak_scaling.json", "rows[*].hop_speedup_pallas",
     "min_frac", 0.50),
]

_TOKEN = re.compile(r"([A-Za-z0-9_]+)|\[(\*|\d+)\]")


def _resolve(obj, path: str):
    """Resolve a dotted path with [i]/[*] list steps; [*] fans out."""
    outs = [obj]
    for tok in _TOKEN.finditer(path):
        key, idx = tok.group(1), tok.group(2)
        nxt = []
        for o in outs:
            if key is not None:
                nxt.append(o[key])
            elif idx == "*":
                nxt.extend(o)
            else:
                nxt.append(o[int(idx)])
        outs = nxt
    return outs


def check_artifact(fresh_path: str, base_path: str, checks) -> list:
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(base_path) as f:
        base = json.load(f)
    # baselines are committed at ONE scale; a fresh artifact from another
    # BENCH_SCALE has different graphs/row counts, so every structural diff
    # would be spurious — skip loudly rather than fail on apples vs oranges
    if fresh.get("scale") != base.get("scale"):
        print(f"  [skip] scale mismatch: fresh={fresh.get('scale')!r} vs "
              f"baseline={base.get('scale')!r} — no comparable checks")
        return []
    failures = []
    for _, path, kind, tol in checks:
        try:
            f_vals = _resolve(fresh, path)
            # max_abs is baseline-free: an older committed baseline need not
            # carry the key at all
            b_vals = ([None] * len(f_vals) if kind == "max_abs"
                      else _resolve(base, path))
        except (KeyError, IndexError, TypeError) as e:
            failures.append((path, kind, f"unresolvable: {e!r}"))
            continue
        if len(f_vals) != len(b_vals):
            failures.append((path, kind,
                             f"fan-out {len(f_vals)} != {len(b_vals)}"))
            continue
        for i, (fv, bv) in enumerate(zip(f_vals, b_vals)):
            tag = path if len(f_vals) == 1 else f"{path}#{i}"
            if kind == "exact":
                ok, want = fv == bv, f"== {bv}"
            elif kind == "min_frac":
                ok, want = fv >= tol * bv, f">= {tol:g}·{bv:.4g}"
            elif kind == "max_rise":
                ok, want = fv <= tol * bv, f"<= {tol:g}·{bv:.4g}"
            elif kind == "max_abs":
                ok, want = fv <= tol, f"<= {tol:g} (absolute)"
            else:
                raise ValueError(kind)
            status = "ok  " if ok else "FAIL"
            print(f"  [{status}] {tag}: {fv:.6g} (want {want})"
                  if isinstance(fv, float) else
                  f"  [{status}] {tag}: {fv} (want {want})")
            if not ok:
                ref = f"absolute ceiling {tol:g}" if kind == "max_abs" \
                    else f"baseline {bv}"
                failures.append((tag, kind, f"{fv} vs {ref}"))
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", default=REPO,
                    help="directory holding the freshly produced BENCH_*.json")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--refresh", action="store_true",
                    help="copy fresh artifacts over the committed baselines")
    args = ap.parse_args()

    artifacts = sorted({c[0] for c in CHECKS})
    if args.refresh:
        os.makedirs(args.baseline_dir, exist_ok=True)
        for name in artifacts:
            src = os.path.join(args.fresh_dir, name)
            shutil.copy(src, os.path.join(args.baseline_dir, name))
            print(f"refreshed baseline {name}")
        return 0

    failures = []
    for name in artifacts:
        fresh = os.path.join(args.fresh_dir, name)
        base = os.path.join(args.baseline_dir, name)
        if not os.path.exists(fresh):
            failures.append((name, "-", "fresh artifact missing"))
            print(f"{name}: FRESH ARTIFACT MISSING ({fresh})")
            continue
        if not os.path.exists(base):
            failures.append((name, "-", "baseline missing"))
            print(f"{name}: BASELINE MISSING ({base}) — run with --refresh")
            continue
        print(f"{name} vs {os.path.relpath(base, REPO)}:")
        failures += check_artifact(fresh, base,
                                   [c for c in CHECKS if c[0] == name])
    if failures:
        print(f"\nBENCH GATE FAILED: {len(failures)} regression(s)")
        for tag, kind, msg in failures:
            print(f"  - {tag} [{kind}]: {msg}")
        return 1
    print("\nBENCH GATE PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
