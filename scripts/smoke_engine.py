"""Dev smoke: engine vs oracle on a small LDBC graph, all modes/splits.

The partitioned executor rides every sweep (n_workers=4): each check asserts
oracle == dense == partitioned, so the distributed path is exercised against
ground truth for plain counts, ETR hops, temporal modes and aggregates
(including MIN/MAX, which now runs partitioned)."""
import sys
import numpy as np

from repro.core import query as Q
from repro.core import engine as E
from repro.core import engine_partitioned as EP
from repro.core.ref_engine import RefEngine
from repro.graphdata.ldbc import LdbcParams, generate_ldbc


def main():
    g = generate_ldbc(LdbcParams(n_persons=60, seed=3, dynamic=False))
    print("graph:", g.subgraph_stats())
    b = g.meta["builder"]
    tp = b.v_type_ids
    te = b.e_type_ids
    k_tag = b.key_ids["tag"]
    k_country = b.key_ids["country"]
    k_int = b.key_ids["hasInterest"]

    tag_v = b.lookup_value(k_tag, "tag1")
    cty = b.lookup_value(k_country, "uk")
    ref = RefEngine(g)

    # Q: person(country=uk) -follows-> person -created-> post(tag=tag1)
    q1 = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(tp["person"], (Q.prop_clause(k_country, "==", cty),)),
            Q.VertexPredicate(tp["person"]),
            Q.VertexPredicate(tp["post"], (Q.prop_clause(k_tag, "in", tag_v),)),
        ),
        e_preds=(
            Q.EdgePredicate(te["follows"], Q.DIR_OUT),
            Q.EdgePredicate(te["created"], Q.DIR_OUT),
        ),
    )
    want = ref.count(q1, mode=E.MODE_STATIC)
    for split in range(3):
        got = E.count_results(g, q1, split=split, mode=E.MODE_STATIC)
        gotp = EP.count_results(g, q1, split=split, n_workers=4)
        print(f"q1 split={split}: got={got} part={gotp} want={want}")
        assert got == gotp == want, (got, gotp, want)

    # ETR query: person -follows-> person -follows-> person with e1 << e2
    q2 = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(tp["person"]),
            Q.VertexPredicate(tp["person"]),
            Q.VertexPredicate(tp["person"], (Q.prop_clause(k_int, "in", tag_v),)),
        ),
        e_preds=(
            Q.EdgePredicate(te["follows"], Q.DIR_OUT),
            Q.EdgePredicate(te["follows"], Q.DIR_OUT, etr_op=0),  # fully before
        ),
    )
    want = ref.count(q2, mode=E.MODE_STATIC)
    for split in range(3):
        got = E.count_results(g, q2, split=split, mode=E.MODE_STATIC)
        gotp = EP.count_results(g, q2, split=split, n_workers=4)
        print(f"q2(etr<<) split={split}: got={got} part={gotp} want={want}")
        assert got == gotp == want, (split, got, gotp, want)

    # ETR overlap + reverse direction hop
    q3 = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(tp["post"]),
            Q.VertexPredicate(tp["person"]),
            Q.VertexPredicate(tp["person"]),
        ),
        e_preds=(
            Q.EdgePredicate(te["created"], Q.DIR_IN),
            Q.EdgePredicate(te["follows"], Q.DIR_BOTH, etr_op=7),  # overlaps
        ),
    )
    want = ref.count(q3, mode=E.MODE_STATIC)
    for split in range(3):
        got = E.count_results(g, q3, split=split, mode=E.MODE_STATIC)
        gotp = EP.count_results(g, q3, split=split, n_workers=4)
        print(f"q3(etr ovl, rev) split={split}: got={got} part={gotp} want={want}")
        assert got == gotp == want, (split, got, gotp, want)

    # bucket mode (dynamic graph)
    gd = generate_ldbc(LdbcParams(n_persons=40, seed=5, dynamic=True))
    bd = gd.meta["builder"]
    refd = RefEngine(gd)
    k_c2 = bd.key_ids["country"]
    ctyd = bd.lookup_value(k_c2, "india")
    q4 = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(bd.v_type_ids["person"], (Q.prop_clause(k_c2, "==", ctyd),)),
            Q.VertexPredicate(bd.v_type_ids["person"]),
        ),
        e_preds=(Q.EdgePredicate(bd.e_type_ids["follows"], Q.DIR_OUT),),
    )
    want = refd.count(q4, mode=E.MODE_BUCKET, n_buckets=16)
    for split in range(2):
        out = E.execute(gd, q4, split=split, mode=E.MODE_BUCKET, n_buckets=16)
        got = np.asarray(out.total)
        gotp = np.asarray(EP.execute(gd, q4, split=split, mode=E.MODE_BUCKET,
                                     n_buckets=16, n_workers=4).total)
        print(f"q4 bucket split={split}: got={got.astype(int)}")
        print(f"                want    ={want.astype(int)}")
        assert np.allclose(got, want), (split, got, want)
        assert np.array_equal(got, gotp), (split, got, gotp)

    # interval mode distinct counts
    want = refd.count(q4, mode=E.MODE_INTERVAL, n_buckets=16)
    for split in range(2):
        got = E.count_results(gd, q4, split=split, mode=E.MODE_INTERVAL, n_buckets=16)
        gotp = EP.count_results(gd, q4, split=split, mode=E.MODE_INTERVAL,
                                n_buckets=16, n_workers=4)
        print(f"q4 interval split={split}: got={got} part={gotp} want={want}")
        assert got == gotp == want, (split, got, gotp, want)

    # aggregation: count persons followed by each person (EQ4-flavoured)
    q5 = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(tp["person"]),
            Q.VertexPredicate(tp["person"]),
        ),
        e_preds=(Q.EdgePredicate(te["follows"], Q.DIR_OUT),),
        agg_op=Q.AGG_COUNT,
    )
    want = ref.aggregate(q5, mode=E.MODE_STATIC)
    out = E.execute(g, q5, mode=E.MODE_STATIC)
    pv = np.asarray(out.per_vertex)
    got = {i: float(pv[i]) for i in np.nonzero(pv)[0]}
    assert got == want, (sorted(got.items())[:5], sorted(want.items())[:5])
    pvp = np.asarray(EP.execute(g, q5, n_workers=4).per_vertex)
    assert np.array_equal(pv, pvp)
    print("q5 aggregate count: OK,", len(got), "groups (dense == partitioned)")

    # MIN/MAX aggregation on the partitioned path (extremum-channel exchange)
    k_len = b.key_ids["length"]
    for op, name in ((Q.AGG_MIN, "min"), (Q.AGG_MAX, "max")):
        q6 = Q.PathQuery(
            v_preds=(
                Q.VertexPredicate(tp["person"]),
                Q.VertexPredicate(tp["post"]),
            ),
            e_preds=(Q.EdgePredicate(te["created"], Q.DIR_OUT),),
            agg_op=op, agg_key=k_len,
        )
        want = ref.aggregate(q6, mode=E.MODE_STATIC)
        out_d = E.execute(g, q6, mode=E.MODE_STATIC)
        out_p = EP.execute(g, q6, mode=E.MODE_STATIC, n_workers=4)
        for label, out in (("dense", out_d), ("partitioned", out_p)):
            pv = np.asarray(out.per_vertex)
            mm = np.asarray(out.minmax)
            got = {i: float(mm[i]) for i in np.nonzero(pv)[0]}
            assert got == want, (name, label,
                                 sorted(got.items())[:5],
                                 sorted(want.items())[:5])
        print(f"q6 aggregate {name}: OK, {len(want)} groups "
              "(dense == partitioned == oracle)")

    print("ALL SMOKE CHECKS PASSED")


if __name__ == "__main__":
    main()
