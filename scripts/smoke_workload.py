"""Dev smoke: Q1-Q8 workload through engine (all splits) vs oracle + planner,
then the same workload through the batched serving scheduler (every engine,
zero per-query fallbacks) cross-checked against the sequential counts."""
import time
import numpy as np

from repro.core import engine as E
from repro.core.planner import Planner
from repro.core.ref_engine import RefEngine
from repro.core.stats import GraphStats
from repro.graphdata.ldbc import LdbcParams, generate_ldbc
from repro.graphdata.queries import make_workload


def smoke_scheduler(g, ref, dynamic):
    """Batched scheduler drain on every engine: counts must match the oracle
    (static mode), every group must dispatch as ONE vmapped call."""
    from repro.serving import BatchScheduler

    wl = make_workload(g, n_per_template=3, seed=1)
    wl += make_workload(g, templates=("Q2", "Q3"), n_per_template=2, seed=4,
                        aggregate=True)
    want = [float(np.sum(ref.count(inst.qry, mode=E.MODE_STATIC)))
            for inst in wl if inst.qry.agg_op == -1]
    for engine in ("auto", "dense", "partitioned"):
        sched = BatchScheduler(g, engine=engine, mode=E.MODE_STATIC,
                               n_workers=2)
        res = sched.run(wl, warm=True)
        n_groups = len(sched.last_dispatches)
        assert sum(d.n_real for d in sched.last_dispatches) == len(wl)
        plain = [r for inst, r in zip(wl, res) if inst.qry.agg_op == -1]
        for w, r in zip(want, plain):
            assert r.count == w, (engine, r.template, r.count, w)
        print(f"  scheduler[{engine}]: {len(wl)} queries in {n_groups} "
              f"batched groups — counts OK")


def main():
    for dynamic in (False, True):
        g = generate_ldbc(LdbcParams(n_persons=80, seed=7, dynamic=dynamic))
        ref = RefEngine(g)
        wl = make_workload(g, n_per_template=2, seed=1)
        stats = GraphStats(g)
        planner = Planner(g, stats)
        print(f"--- dynamic={dynamic}: {g.subgraph_stats()}, {len(wl)} queries")
        print("stats size:", stats.size_report())
        for inst in wl:
            want = ref.count(inst.qry, mode=E.MODE_STATIC)
            for split in range(inst.qry.n_vertices):
                got = E.count_results(g, inst.qry, split=split, mode=E.MODE_STATIC)
                assert got == want, (inst.template, split, got, want)
            est = planner.choose(inst.qry)
            print(f"{inst.template}: count={want:8.0f}  plan={est.split} "
                  f"t̂={est.t_ms:.2f}ms")
        # aggregate workload, bucket mode on dynamic graph
        wla = make_workload(g, templates=("Q2", "Q4"), n_per_template=1, seed=2,
                            aggregate=True)
        for inst in wla:
            mode = E.MODE_BUCKET if dynamic else E.MODE_STATIC
            out = E.execute(g, inst.qry, mode=mode, n_buckets=16)
            if dynamic:
                want = ref.aggregate(inst.qry, mode=E.MODE_BUCKET, n_buckets=16)
                got = np.asarray(out.per_vertex)
                assert np.allclose(got, want), (inst.template, np.abs(got - want).max())
            else:
                want = ref.aggregate(inst.qry, mode=E.MODE_STATIC)
                pv = np.asarray(out.per_vertex)
                got = {i: float(pv[i]) for i in np.nonzero(pv)[0]}
                assert got == want, inst.template
            print(f"{inst.template} aggregate ({'bucket' if dynamic else 'static'}): OK")
        smoke_scheduler(g, ref, dynamic)
    print("WORKLOAD SMOKE PASSED")


if __name__ == "__main__":
    main()
