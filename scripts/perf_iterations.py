import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: baseline → optimised iterations for the three
chosen cells, each lowered+compiled on the single-pod mesh and analysed with
the roofline pipeline.  Results → experiments/perf/<cell>__<iter>.json.

Chosen cells (see EXPERIMENTS.md §Perf for the hypothesis log):
  1. llama3-405b × decode_32k   — worst serving cell (HBM/ICI blowup)
  2. mixtral-8x22b × train_4k   — most collective/memory-bound train cell
  3. granite-ldbc × q3hop_etr   — the paper's own technique
     (+ warp_2hop, its dynamic-mode variant)
"""
import dataclasses
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import common, load_arch
from repro.configs import granite_ldbc as GL
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import analyze_compiled

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")


def record(tag, cell, mesh, model_flops=None, scan_trips=None):
    t0 = time.time()
    with mesh:
        fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings)
        compiled = fn.lower(*cell.args).compile()
        rep = analyze_compiled(
            compiled, mesh.devices.size, tag, "", "single",
            model_flops=model_flops, scan_trips=scan_trips,
            analytic_flops=getattr(cell, "analytic_flops", None))
    rec = rep.to_json()
    rec["t_compile_s"] = time.time() - t0
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=2)
    m = rec.get("memory_per_device") or {}
    print(f"[{tag}] tc={rec['t_compute']*1e3:.2f}ms tm={rec['t_memory']*1e3:.2f}ms "
          f"tx={rec['t_collective']*1e3:.2f}ms bott={rec['bottleneck']} "
          f"temp={m.get('temp_bytes',0)/1e9:.1f}GB arg={m.get('argument_bytes',0)/1e9:.1f}GB",
          flush=True)
    return rec


# ---------------------------------------------------------------- LM cells
def lm_iter(arch_id, shape, itname, mesh, **cfg_overrides):
    import importlib
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    cfg = dataclasses.replace(mod.CONFIG, **cfg_overrides)
    cell = common.lm_cell(cfg, shape, mesh)
    spec = load_arch(arch_id)
    from repro.launch.dryrun import model_flops_for
    mf = model_flops_for(arch_id, shape, spec)
    return record(f"{arch_id}__{shape}__{itname}", cell, mesh,
                  model_flops=mf, scan_trips=cfg.n_layers)


# ------------------------------------------------------------ granite cells
def granite_sliced_cell(shape_name, mesh):
    """Type-sliced variant of a granite dry-run cell (synthetic slice bounds
    at 100k:F scale; fractions from paper Table 4 arrival mix)."""
    from repro.core import engine_sliced as ES
    from repro.core import query as Q

    V, E2 = GL.V_FULL, 2 * GL.E_FULL
    # type layout: person, post, comment, forum (fractions of V)
    fr_v = [0.002, 0.243, 0.736, 0.019]
    fr_e = [0.20, 0.35, 0.40, 0.05]      # traversal arrivals per type
    v_bounds, e_bounds = [], []
    va = ea = 0
    for i, (fv, fe) in enumerate(zip(fr_v, fr_e)):
        vb = V if i == 3 else int(va + fv * V)
        eb = E2 if i == 3 else int(ea + fe * E2)
        v_bounds.append((va, vb))
        e_bounds.append((ea, eb))
        va, ea = vb, eb
    sb = ES.SliceBounds(tuple(v_bounds), tuple(e_bounds))

    info = GL.SHAPES[shape_name]
    qry = info["qf"]()
    split, mode = info["split"], info["mode"]
    n_buckets = 16
    gdev_sds = GL._gdev_sds(V, E2, n_buckets)
    gdev_sh = GL._gdev_shardings(mesh, V, E2)
    params_sds = common.sds(Q.query_params(qry).shape, jnp.int32)
    bedges_sds = common.sds((n_buckets + 1,), jnp.int32)

    def run(gdev, params, bedges):
        out = ES.execute_plan_sliced(gdev, qry, split, mode, n_buckets,
                                     params, bedges, sb)
        if info["agg"]:
            return out.total, out.per_vertex
        return out.total

    if info["agg"]:
        # per-vertex output lives on the first-type slice → replicate spec
        out_sh = (common.named(mesh, P()), common.named(
            mesh, P(None) if mode == 0 else P(None, None)))
    else:
        out_sh = common.named(mesh, P() if mode == 0 else P(None))
    cell = common.ShapeCell(
        run, (gdev_sds, params_sds, bedges_sds),
        (gdev_sh, common.named(mesh, P(None, None)), common.named(mesh, P(None))),
        out_sh, "query", analytic_flops=GL.analytic_flops(shape_name),
    )
    return cell


def main():
    mesh = make_production_mesh(multi_pod=False)

    which = sys.argv[1] if len(sys.argv) > 1 else "all"

    if which in ("all", "llama"):
        print("=== cell 1: llama3-405b decode_32k ===")
        lm_iter("llama3-405b", "decode_32k", "it0_baseline", mesh)
        lm_iter("llama3-405b", "decode_32k", "it1_gqa_native", mesh,
                gqa_native=True)

    if which in ("all", "llama2"):
        lm_iter("llama3-405b", "decode_32k", "it2_kv_constraint", mesh,
                gqa_native=True, decode_kv_constraint="dh")

    if which in ("all", "llama3"):
        lm_iter("llama3-405b", "decode_32k", "it3_kv_quant", mesh,
                gqa_native=True, kv_cache_quant=True)

    if which in ("all", "mixtral"):
        print("=== cell 2: mixtral-8x22b train_4k ===")
        lm_iter("mixtral-8x22b", "train_4k", "it0_baseline", mesh)
        lm_iter("mixtral-8x22b", "train_4k", "it1_moe_scan", mesh,
                moe_group_map="scan")
        lm_iter("mixtral-8x22b", "train_4k", "it2_gqa_native", mesh,
                moe_group_map="scan", gqa_native=True)

    if which in ("all", "mixtral2"):
        lm_iter("mixtral-8x22b", "train_4k", "it3_remat_inner", mesh,
                moe_group_map="scan", gqa_native=True, remat_inner=True)

    if which in ("all", "granite"):
        print("=== cell 3: granite-ldbc q3hop_etr (+ warp_2hop) ===")
        spec = load_arch("granite-ldbc")
        for shape in ("q3hop_etr", "warp_2hop"):
            cell0 = spec.shapes[shape](mesh)
            record(f"granite-ldbc__{shape}__it0_baseline", cell0, mesh)
            cell1 = granite_sliced_cell(shape, mesh)
            record(f"granite-ldbc__{shape}__it1_sliced", cell1, mesh)


if __name__ == "__main__":
    main()
