"""Fig 10/11 analogue: per-template average latency, Granite-JAX (planned)
vs no-planner vs single-threaded Python baseline engine (Neo4J-class proxy —
see DESIGN.md §8.3)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine as E
from repro.core.ref_engine import RefEngine
from repro.graphdata.ldbc import graph_name
from repro.graphdata.queries import make_workload
from repro.launch.query import GraniteServer

from .common import N_QUERIES, bench_graphs, emit, get_graph

BASELINE_BUDGET_S = 20.0


def run(aggregate: bool = False):
    for params in bench_graphs():
        g = get_graph(params)
        name = graph_name(params)
        wl = make_workload(g, n_per_template=N_QUERIES, seed=21,
                           aggregate=aggregate)
        server = GraniteServer(g, use_planner=True)
        recs = server.run_workload(wl)
        ref = RefEngine(g, max_expansions=20_000_000)
        by_t = {}
        for inst, rec in zip(wl, recs):
            by_t.setdefault(inst.template, dict(gr=[], base=[], dnf=0))
            by_t[inst.template]["gr"].append(rec.latency_ms)
        # baseline: python enumeration with a budget (first 2 per template)
        done = {}
        for inst in wl:
            k = inst.template
            if done.get(k, 0) >= 2:
                continue
            done[k] = done.get(k, 0) + 1
            t0 = time.perf_counter()
            try:
                if aggregate:
                    ref.aggregate(inst.qry, mode=E.MODE_STATIC)
                else:
                    ref.count(inst.qry, mode=E.MODE_STATIC)
                dt = (time.perf_counter() - t0) * 1e3
                if dt > BASELINE_BUDGET_S * 1e3:
                    by_t[k]["dnf"] += 1
                else:
                    by_t[k]["base"].append(dt)
            except RuntimeError:
                by_t[k]["dnf"] += 1
        tag = "agg" if aggregate else "nonagg"
        for t, d in sorted(by_t.items()):
            gr = np.mean(d["gr"])
            base = np.mean(d["base"]) if d["base"] else float("nan")
            speedup = base / gr if d["base"] else float("nan")
            emit(f"latency_{tag}/{name}/{t}", gr * 1e3,
                 f"baseline_ms={base:.1f};speedup={speedup:.1f}x;dnf={d['dnf']}")


def main():
    run(aggregate=False)


if __name__ == "__main__":
    main()
