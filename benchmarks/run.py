"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  BENCH_SCALE=full for the
larger configuration; default is CI-sized (minutes).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (aggregates, completion, components, cost_model,
                   fit_cost_model, latency, roofline_report, weak_scaling)
    from .common import ROWS

    suites = [
        ("fit_cost_model (paper Tbl 3)", fit_cost_model.run),
        ("latency non-aggregate (paper Fig 10/11)", lambda: latency.run(False)),
        ("latency aggregate (paper Fig 12)", aggregates.run),
        ("cost model (paper Fig 8/9, Tbl 6)", cost_model.run),
        ("completion (paper Tbl 7)", completion.run),
        ("components (paper Fig 13)", components.run),
        ("weak scaling (paper Fig 14)", weak_scaling.run),
        ("roofline (assignment §Roofline)", roofline_report.run),
    ]
    failures = 0
    for name, fn in suites:
        print(f"#\n# === {name} ===", flush=True)
        try:
            fn()
        except Exception:
            failures += 1
            print(f"# SUITE FAILED: {name}", flush=True)
            traceback.print_exc()
    print(f"#\n# benchmarks complete: {len(ROWS)} rows, {failures} suite failures")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
