"""Shared benchmark utilities: graph cache, timing, CSV emission."""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.graphdata.ldbc import LdbcParams, generate_ldbc, graph_name

# BENCH_SCALE=full uses larger graphs (minutes); default is CI-sized.
SCALE = os.environ.get("BENCH_SCALE", "ci")
N_PERSONS = {"ci": 400, "full": 2000}[SCALE]
N_QUERIES = {"ci": 5, "full": 25}[SCALE]

_GRAPH_CACHE: Dict[str, object] = {}

ROWS: List[str] = []


def bench_graphs(dists=("facebook", "zipf"), dynamic_too=True):
    out = []
    for dist in dists:
        p = LdbcParams(n_persons=N_PERSONS, degree_dist=dist, dynamic=False, seed=2)
        out.append(p)
        if dynamic_too:
            out.append(LdbcParams(n_persons=N_PERSONS // 2, degree_dist=dist,
                                  dynamic=True, seed=2))
    return out


def get_graph(params: LdbcParams):
    key = graph_name(params)
    if key not in _GRAPH_CACHE:
        _GRAPH_CACHE[key] = generate_ldbc(params)
    return _GRAPH_CACHE[key]


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn, repeat: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat * 1e6   # µs


def hop_delivery_times(g, mode: int, n_buckets: int = 8,
                       repeats: int = 5) -> dict:
    """Measured one-hop delivery cost per impl on ``g``'s traversal arrays.

    Times exactly the step the θ_scatter coefficients model and the fused
    kernel replaces: gather source state at ``t_src`` → apply an edge mask →
    segment-reduce by ``t_dst`` — as the materialize+segment_sum XLA path
    and as the fused hop kernel over the graph's static block layout.
    Integer-valued state (the engine's count invariant) keeps the two paths
    bit-identical, asserted here so the timing can never drift off a broken
    kernel.  Returns {'xla_ms', 'pallas_ms', 'speedup', 'edges'}.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import engine as E
    from repro.core import intervals as iv
    from repro.core import superstep as SS

    rng = np.random.default_rng(7)
    gdev = E._prepare_gdev(g)
    t_src, t_dst = gdev["t_src"], gdev["t_dst"]
    V, E2 = g.n_vertices, int(t_src.shape[0])
    bedges = jnp.asarray(iv.bucket_edges(g.lifespan[0], g.lifespan[1],
                                         n_buckets))
    ts = () if mode == SS.MODE_STATIC else (n_buckets,)
    state = jnp.asarray(rng.integers(0, 8, (V,) + ts).astype(np.float32))
    wmask = jnp.asarray(rng.random(E2) < 0.6)
    evalid = (None if mode == SS.MODE_STATIC
              else jnp.asarray(rng.random((E2, n_buckets)) < 0.7))
    layout = E.hop_layout_for(g)

    def xla_hop(state, wmask, evalid, seg):
        cnt = SS.apply_edge(state[t_src], wmask, evalid, mode)
        return SS.deliver(cnt, seg, V)

    def pallas_hop(state, wmask, evalid):
        with SS.bucket_scope(bedges):
            return SS.fused_hop_deliver(state, t_src, wmask, evalid, mode,
                                        layout.tables, layout.block_v, V,
                                        impl="pallas")[0]

    fx = jax.jit(xla_hop)
    fp = jax.jit(pallas_hop)
    a = fx(state, wmask, evalid, t_dst)
    b = fp(state, wmask, evalid)
    assert np.array_equal(np.asarray(a), np.asarray(b)), \
        "fused hop drifted off the XLA delivery"

    def best_of(fn, *args):
        t_best = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            t_best = min(t_best, time.perf_counter() - t0)
        return t_best * 1e3

    t_x = best_of(fx, state, wmask, evalid, t_dst)
    t_p = best_of(fp, state, wmask, evalid)
    return dict(xla_ms=t_x, pallas_ms=t_p, speedup=t_x / max(t_p, 1e-9),
                edges=E2)
