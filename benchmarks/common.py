"""Shared benchmark utilities: graph cache, timing, CSV emission."""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.graphdata.ldbc import LdbcParams, generate_ldbc, graph_name

# BENCH_SCALE=full uses larger graphs (minutes); default is CI-sized.
SCALE = os.environ.get("BENCH_SCALE", "ci")
N_PERSONS = {"ci": 400, "full": 2000}[SCALE]
N_QUERIES = {"ci": 5, "full": 25}[SCALE]

_GRAPH_CACHE: Dict[str, object] = {}

ROWS: List[str] = []


def bench_graphs(dists=("facebook", "zipf"), dynamic_too=True):
    out = []
    for dist in dists:
        p = LdbcParams(n_persons=N_PERSONS, degree_dist=dist, dynamic=False, seed=2)
        out.append(p)
        if dynamic_too:
            out.append(LdbcParams(n_persons=N_PERSONS // 2, degree_dist=dist,
                                  dynamic=True, seed=2))
    return out


def get_graph(params: LdbcParams):
    key = graph_name(params)
    if key not in _GRAPH_CACHE:
        _GRAPH_CACHE[key] = generate_ldbc(params)
    return _GRAPH_CACHE[key]


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn, repeat: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat * 1e6   # µs
