"""Micro-benchmark fit of the cost-model execution-time coefficients
(paper Table 3 analogue) — writes src/repro/configs/cost_coeffs.json.

Features per measured superstep batch:
  [1, V_slice, E_slice, etr·E_slice, m̄, m_net_state, m_net_etr]
where the first five come from dense single-stream runs (exchange columns 0)
and the two PER-CHANNEL exchange columns come from MEASURED partitioned
supersteps (engine_partitioned.measure_supersteps): per-worker compute
extents divide by the worker count, and the boundary volumes are the ragged
point-to-point lane contents the executor actually moves — halo ghost
entries for the vertex-state channel (the MIN/MAX extremum channel rides
the same lanes, so its rows double the state column), boundary rank
summaries (cut edges) for the ETR channel.  The fitted θ_net / θ_net_etr
pair makes plan selection distribution-aware per channel.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine as E
from repro.core import engine_partitioned as EP
from repro.core import query as Q
from repro.core.planner import fit_linear, load_coeffs, save_coeffs
from repro.core.stats import GraphStats
from repro.graphdata.ldbc import LdbcParams, generate_ldbc
from repro.graphdata.queries import make_workload

from .common import SCALE, emit, hop_delivery_times


def _trav_by_type(g):
    """Traversal arrivals per vertex type (same derivation as Planner)."""
    deg = g.in_degree.astype(np.int64) + g.out_degree.astype(np.int64)
    out = np.zeros(g.n_vertex_types, np.int64)
    np.add.at(out, g.v_type, deg)
    return out


def _step_features(g, qry, trav_by_type, V, E2):
    """Per-superstep (v_slice, e_slice, etr) extents for a query's hops."""
    n_steps = qry.n_vertices
    v_slices, e_slices, etrs = [], [], []
    for i, vp in enumerate(qry.v_preds):
        v_slices.append(g.type_counts[vp.vtype] if vp.vtype >= 0 else V)
        nxt = qry.v_preds[i + 1].vtype if i + 1 < n_steps else -1
        e_slices.append(trav_by_type[nxt] if nxt >= 0 else E2)
        etrs.append(1.0 if (i < len(qry.e_preds) and
                            qry.e_preds[i].etr_op != -1) else 0.0)
    return np.asarray(v_slices, float), np.asarray(e_slices, float), np.asarray(etrs)


def run(write: bool = True):
    sizes = {"ci": (150, 400), "full": (400, 1200)}[SCALE]
    part_workers = {"ci": (2, 4), "full": (2, 4, 8)}[SCALE]
    rows, times = [], []
    graphs = []
    for n in sizes:
        g = generate_ldbc(LdbcParams(n_persons=n, degree_dist="facebook", seed=6))
        graphs.append(g)
        V, E2 = g.n_vertices, 2 * g.n_edges
        trav_by_type = _trav_by_type(g)
        wl = make_workload(g, n_per_template=3, seed=61)
        for inst in wl:
            qry = inst.qry
            for split in (0, qry.n_vertices - 1):
                E.count_results(g, qry, split=split)  # compile
                t0 = time.perf_counter()
                for _ in range(3):
                    out = E.execute(g, qry, split=split)
                t = (time.perf_counter() - t0) / 3 * 1e3
                v_s, e_s, etrs = _step_features(g, qry, trav_by_type, V, E2)
                feats = np.asarray([
                    qry.n_vertices,
                    float(np.sum(v_s)),
                    float(np.sum(e_s[:-1])),
                    float(np.sum(etrs[:-1] * e_s[:-1])),
                    float(np.sum(e_s[:-1])) * 0.05,  # message proxy
                    0.0,                             # no exchange single-stream
                    0.0,
                ])
                rows.append(feats)
                times.append(t)

    # ---- partitioned supersteps: measured per-worker makespans + the
    # per-channel ragged exchange volumes (state incl. extremum, ETR)
    g = graphs[0]
    V, E2 = g.n_vertices, 2 * g.n_edges
    trav_by_type = _trav_by_type(g)
    wl = make_workload(g, templates=("Q1", "Q2", "Q4"), n_per_template=2, seed=62)
    # a MIN/MAX instance so the extremum channel (state lanes ×2) is in the
    # fitted population, not just modelled — same construction the serving
    # bench and the multidevice tests use (queries.to_minmax)
    from repro.graphdata.queries import to_minmax
    qmm = to_minmax(
        make_workload(g, templates=("Q2",), n_per_template=1, seed=63)[0],
        g).qry
    queries = [inst.qry for inst in wl] + [qmm]
    for w in part_workers:
        for qry in queries:
            prof = EP.measure_supersteps(g, qry, n_workers=w, repeats=2)
            t = float(prof.makespan_s.sum()) * 1e3  # ms, straggler per hop
            fq = qry.reversed() if qry.agg_op != Q.AGG_NONE else qry
            v_s, e_s, etrs = _step_features(g, fq, trav_by_type, V, E2)
            ch = prof.channel_totals()
            # features must describe what measure_supersteps TIMES: one
            # dispatch per hop of local compute (edge apply + delivery +
            # halo gather; on ETR hops also the per-worker rank-summary
            # prefix tables) — init predicate eval and the final join are
            # untimed there, so those columns are zeroed for these rows.
            feats = np.asarray([
                len(qry.e_preds),
                0.0,
                float(np.sum(e_s[:-1])) / w,
                float(np.sum(etrs[:-1] * e_s[:-1])) / w,
                float(np.sum(e_s[:-1])) * 0.05 / w,
                float(ch["state"] + ch["extremum"]),
                float(ch["etr"]),
            ])
            rows.append(feats)
            times.append(t)

    X = np.asarray(rows)
    y = np.asarray(times)
    # Two-stage fit: the compute coefficients come from the dense rows alone
    # (same conditioning as the seed fit); the two per-channel θ_net's then
    # explain the residual of the partitioned rows over their compute share —
    # this keeps the two row populations from fighting over the collinear
    # compute columns.
    dense_sel = (X[:, 5] == 0.0) & (X[:, 6] == 0.0)
    theta_c = np.maximum(fit_linear(X[dense_sel, :5], y[dense_sel]), 0.0)
    resid = y[~dense_sel] - X[~dense_sel, :5] @ theta_c
    M = X[~dense_sel, 5:7]
    theta_net_pair = np.maximum(fit_linear(M, resid), 0.0)
    theta = np.concatenate([theta_c, theta_net_pair])

    # ---- per-impl hop-delivery slopes (θ_scatter): the same one-hop
    # delivery timed as the materialize+segment_sum XLA path and as the
    # fused hop kernel, over both micro-bench graphs and both cheap modes;
    # an origin-constrained least squares gives ms-per-edge per impl.  These
    # are the coefficients choose(impls=...) discriminates on, so they are
    # fitted from the exact step the impl axis swaps.
    edges, t_xla, t_pal = [], [], []
    for g_ in graphs:
        for md in (E.MODE_STATIC, E.MODE_BUCKET):
            r = hop_delivery_times(g_, md, n_buckets=8)
            edges.append(float(r["edges"]))
            t_xla.append(r["xla_ms"])
            t_pal.append(r["pallas_ms"])
    ee = np.asarray(edges)
    denom = max(float(np.sum(ee * ee)), 1e-9)
    scatter_xla = float(np.sum(np.asarray(t_xla) * ee) / denom)
    scatter_pal = float(np.sum(np.asarray(t_pal) * ee) / denom)

    coeffs = dict(
        theta0=float(theta[0]), theta_init=float(theta[1]),
        theta_v=float(theta[1]), theta_e=float(theta[2]),
        theta_etr=float(theta[3]), theta_m=float(theta[4]),
        theta_net=float(theta_net_pair[0]),
        theta_net_etr=float(theta_net_pair[1]),
        theta_scatter_xla=scatter_xla,
        theta_scatter_pallas=scatter_pal,
    )
    pred = X @ theta
    r2 = 1 - np.sum((y - pred) ** 2) / max(np.sum((y - y.mean()) ** 2), 1e-9)
    if write:
        save_coeffs(coeffs)
    emit("fit_cost_model/r2", 0.0, f"r2={r2:.3f};n={len(y)};coeffs={coeffs}")
    return coeffs


def main():
    run()


if __name__ == "__main__":
    main()
