"""Micro-benchmark fit of the cost-model execution-time coefficients
(paper Table 3 analogue) — writes src/repro/configs/cost_coeffs.json.

Features per measured superstep batch:
  [1, V_slice, E_slice, etr·E_slice, m̄, m_net]
where the first five come from dense single-stream runs (m_net = 0) and the
exchange column m_net comes from MEASURED partitioned supersteps
(engine_partitioned.measure_supersteps): per-worker compute extents divide by
the worker count, the boundary-message volume is the partitioner's halo
ghost count on plain hops and its boundary rank-summary count (cut edges)
on ETR hops — the volumes the partitioned executor actually exchanges.  The
fitted θ_net makes plan selection distribution-aware.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine as E
from repro.core import engine_partitioned as EP
from repro.core.planner import fit_linear, load_coeffs, save_coeffs
from repro.core.stats import GraphStats
from repro.graphdata.ldbc import LdbcParams, generate_ldbc
from repro.graphdata.queries import make_workload

from .common import SCALE, emit


def _trav_by_type(g):
    """Traversal arrivals per vertex type (same derivation as Planner)."""
    deg = g.in_degree.astype(np.int64) + g.out_degree.astype(np.int64)
    out = np.zeros(g.n_vertex_types, np.int64)
    np.add.at(out, g.v_type, deg)
    return out


def _step_features(g, qry, trav_by_type, V, E2):
    """Per-superstep (v_slice, e_slice, etr) extents for a query's hops."""
    n_steps = qry.n_vertices
    v_slices, e_slices, etrs = [], [], []
    for i, vp in enumerate(qry.v_preds):
        v_slices.append(g.type_counts[vp.vtype] if vp.vtype >= 0 else V)
        nxt = qry.v_preds[i + 1].vtype if i + 1 < n_steps else -1
        e_slices.append(trav_by_type[nxt] if nxt >= 0 else E2)
        etrs.append(1.0 if (i < len(qry.e_preds) and
                            qry.e_preds[i].etr_op != -1) else 0.0)
    return np.asarray(v_slices, float), np.asarray(e_slices, float), np.asarray(etrs)


def run(write: bool = True):
    sizes = {"ci": (150, 400), "full": (400, 1200)}[SCALE]
    part_workers = {"ci": (2, 4), "full": (2, 4, 8)}[SCALE]
    rows, times = [], []
    graphs = []
    for n in sizes:
        g = generate_ldbc(LdbcParams(n_persons=n, degree_dist="facebook", seed=6))
        graphs.append(g)
        V, E2 = g.n_vertices, 2 * g.n_edges
        trav_by_type = _trav_by_type(g)
        wl = make_workload(g, n_per_template=3, seed=61)
        for inst in wl:
            qry = inst.qry
            for split in (0, qry.n_vertices - 1):
                E.count_results(g, qry, split=split)  # compile
                t0 = time.perf_counter()
                for _ in range(3):
                    out = E.execute(g, qry, split=split)
                t = (time.perf_counter() - t0) / 3 * 1e3
                v_s, e_s, etrs = _step_features(g, qry, trav_by_type, V, E2)
                feats = np.asarray([
                    qry.n_vertices,
                    float(np.sum(v_s)),
                    float(np.sum(e_s[:-1])),
                    float(np.sum(etrs[:-1] * e_s[:-1])),
                    float(np.sum(e_s[:-1])) * 0.05,  # message proxy
                    0.0,                             # no exchange single-stream
                ])
                rows.append(feats)
                times.append(t)

    # ---- partitioned supersteps: measured per-worker makespans + exchange
    g = graphs[0]
    V, E2 = g.n_vertices, 2 * g.n_edges
    trav_by_type = _trav_by_type(g)
    wl = make_workload(g, templates=("Q1", "Q2", "Q4"), n_per_template=2, seed=62)
    for w in part_workers:
        for inst in wl:
            qry = inst.qry
            prof = EP.measure_supersteps(g, qry, n_workers=w, repeats=2)
            t = float(prof.makespan_s.sum()) * 1e3  # ms, straggler per hop
            v_s, e_s, etrs = _step_features(g, qry, trav_by_type, V, E2)
            # features must describe what measure_supersteps TIMES: one
            # dispatch per hop of local compute (edge apply + delivery +
            # halo gather; on ETR hops also the per-worker rank-summary
            # prefix tables) — init predicate eval and the final join are
            # untimed there, so those columns are zeroed for these rows.
            feats = np.asarray([
                len(qry.e_preds),
                0.0,
                float(np.sum(e_s[:-1])) / w,
                float(np.sum(etrs[:-1] * e_s[:-1])) / w,
                float(np.sum(e_s[:-1])) * 0.05 / w,
                float(prof.exchange_msgs.sum()),
            ])
            rows.append(feats)
            times.append(t)

    X = np.asarray(rows)
    y = np.asarray(times)
    # Two-stage fit: the compute coefficients come from the dense rows alone
    # (same conditioning as the seed fit); θ_net then explains the residual
    # of the partitioned rows over their compute share — this keeps the two
    # row populations from fighting over the collinear compute columns.
    dense_sel = X[:, 5] == 0.0
    theta_c = np.maximum(fit_linear(X[dense_sel, :5], y[dense_sel]), 0.0)
    resid = y[~dense_sel] - X[~dense_sel, :5] @ theta_c
    m_net = X[~dense_sel, 5]
    theta_net = float(np.maximum(
        np.dot(m_net, resid) / max(np.dot(m_net, m_net), 1e-9), 0.0))
    theta = np.concatenate([theta_c, [theta_net]])
    coeffs = dict(
        theta0=float(theta[0]), theta_init=float(theta[1]),
        theta_v=float(theta[1]), theta_e=float(theta[2]),
        theta_etr=float(theta[3]), theta_m=float(theta[4]),
        theta_net=theta_net,
    )
    pred = X @ theta
    r2 = 1 - np.sum((y - pred) ** 2) / max(np.sum((y - y.mean()) ** 2), 1e-9)
    if write:
        save_coeffs(coeffs)
    emit("fit_cost_model/r2", 0.0, f"r2={r2:.3f};n={len(y)};coeffs={coeffs}")
    return coeffs


def main():
    run()


if __name__ == "__main__":
    main()
