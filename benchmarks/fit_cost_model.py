"""Micro-benchmark fit of the cost-model execution-time coefficients
(paper Table 3 analogue) — writes src/repro/configs/cost_coeffs.json.

Features per measured superstep: [1, V_slice, E_slice, etr·E_slice, m̄].
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine as E
from repro.core.planner import fit_linear, load_coeffs, save_coeffs
from repro.core.stats import GraphStats
from repro.graphdata.ldbc import LdbcParams, generate_ldbc
from repro.graphdata.queries import make_workload

from .common import SCALE, emit


def run(write: bool = True):
    sizes = {"ci": (150, 400), "full": (400, 1200)}[SCALE]
    rows, times = [], []
    for n in sizes:
        g = generate_ldbc(LdbcParams(n_persons=n, degree_dist="facebook", seed=6))
        V, E2 = g.n_vertices, 2 * g.n_edges
        deg = g.in_degree.astype(np.int64) + g.out_degree.astype(np.int64)
        trav_by_type = np.zeros(g.n_vertex_types, np.int64)
        np.add.at(trav_by_type, g.v_type, deg)
        wl = make_workload(g, n_per_template=3, seed=61)
        for inst in wl:
            qry = inst.qry
            for split in (0, qry.n_vertices - 1):
                E.count_results(g, qry, split=split)  # compile
                t0 = time.perf_counter()
                for _ in range(3):
                    out = E.execute(g, qry, split=split)
                t = (time.perf_counter() - t0) / 3 * 1e3
                n_steps = qry.n_vertices
                # distribute time over supersteps with per-step features
                v_slices, e_slices, etrs, msgs = [], [], [], []
                for i, vp in enumerate(qry.v_preds):
                    v_slices.append(
                        g.type_counts[vp.vtype] if vp.vtype >= 0 else V)
                    nxt = qry.v_preds[i + 1].vtype if i + 1 < n_steps else -1
                    e_slices.append(trav_by_type[nxt] if nxt >= 0 else E2)
                    etrs.append(1.0 if (i < len(qry.e_preds) and
                                        qry.e_preds[i].etr_op != -1) else 0.0)
                feats = np.asarray([
                    n_steps,
                    float(np.sum(v_slices)),
                    float(np.sum(e_slices[:-1])),
                    float(np.sum(np.asarray(etrs[:-1]) * np.asarray(e_slices[:-1]))),
                    float(np.sum(e_slices[:-1])) * 0.05,  # message proxy
                ])
                rows.append(feats)
                times.append(t)
    X = np.asarray(rows)
    y = np.asarray(times)
    theta = fit_linear(X, y)
    theta = np.maximum(theta, 0.0)  # physical non-negativity
    coeffs = dict(
        theta0=float(theta[0]), theta_init=float(theta[1]),
        theta_v=float(theta[1]), theta_e=float(theta[2]),
        theta_etr=float(theta[3]), theta_m=float(theta[4]),
    )
    pred = X @ theta
    r2 = 1 - np.sum((y - pred) ** 2) / max(np.sum((y - y.mean()) ** 2), 1e-9)
    if write:
        save_coeffs(coeffs)
    emit("fit_cost_model/r2", 0.0, f"r2={r2:.3f};n={len(y)};coeffs={coeffs}")
    return coeffs


def main():
    run()


if __name__ == "__main__":
    main()
