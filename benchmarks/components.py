"""Fig 13 analogue: component times per superstep (init/compute, scatter,
delivery, ETR) measured with an instrumented eager runner."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as E
from repro.core import query as Q
from repro.graphdata.ldbc import graph_name
from repro.graphdata.queries import make_workload

from .common import bench_graphs, emit, get_graph


def _timed(fn, *a):
    t0 = time.perf_counter()
    out = fn(*a)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) * 1e3


def component_times(g, qry: Q.PathQuery) -> dict:
    """Eager per-phase timing of a left-to-right execution."""
    gdev = E._prepare_gdev(g)
    import repro.core.intervals as iv
    bedges = jnp.asarray(iv.bucket_edges(g.lifespan[0], g.lifespan[1], 16))
    E._TRACE_BEDGES.append(None)
    pv, pe = E._pbases(qry)
    params = jnp.asarray(Q.query_params(qry))
    phases = {}
    try:
        V = gdev["v_life"].shape[0]
        # init
        (vm, vv), t = _timed(
            E._eval_predicate, gdev["vprops"], gdev["v_type"], gdev["v_life"],
            qry.v_preds[0].vtype, qry.v_preds[0].clauses, params, pv[0], 0, None)
        phases["init"] = t
        state = vm.astype(jnp.float32)
        prev_raw = None
        for i, ep in enumerate(qry.e_preds):
            (wmask, _), t_s = _timed(
                E._edge_predicate_weights, gdev, ep, params, pe[i], 0, None)
            if i > 0:
                (vm, vv), t_c = _timed(
                    E._eval_predicate, gdev["vprops"], gdev["v_type"],
                    gdev["v_life"], qry.v_preds[i].vtype, qry.v_preds[i].clauses,
                    params, pv[i], 0, None)
                phases[f"compute_{i}"] = t_c
            if ep.etr_op != -1 and prev_raw is not None:
                src_cnt, t_etr = _timed(
                    E._etr_weighted, gdev, prev_raw, ep.etr_op, False, False)
                phases[f"etr_{i}"] = t_etr
                src_val = src_cnt * vm[gdev["t_src"]].astype(jnp.float32)
            else:
                sv = state if i == 0 else arrivals * vm.astype(jnp.float32)
                src_val = sv[gdev["t_src"]]
            cnt_e = src_val * wmask.astype(jnp.float32)
            phases[f"scatter_{i}"] = t_s
            (arrivals,), t_d = _timed(
                lambda c: (jax.ops.segment_sum(c, gdev["t_dst"], num_segments=V,
                                               indices_are_sorted=True),), cnt_e)
            phases[f"deliver_{i}"] = t_d
            prev_raw = cnt_e
    finally:
        E._TRACE_BEDGES.pop()
    return phases


def run():
    params = bench_graphs(dynamic_too=False)[0]
    g = get_graph(params)
    name = graph_name(params)
    wl = make_workload(g, templates=("Q7", "Q3"), n_per_template=2, seed=30)
    for inst in wl[::2]:
        ph = component_times(g, inst.qry)
        total = sum(ph.values())
        detail = ";".join(f"{k}={v:.2f}ms" for k, v in ph.items())
        emit(f"components/{name}/{inst.template}", total * 1e3, detail)


def main():
    run()


if __name__ == "__main__":
    main()
