"""Fig 14 analogue: weak scaling — graph size ∝ worker count.

Unlike the seed (which only *modelled* per-worker makespan from partition
edge extents), this executes the PARTITIONED engine for real: each worker's
local superstep (halo gather → edge apply → local segment-sum delivery) is
run and timed separately (engine_partitioned.measure_supersteps), so the
reported quantities are measured wall-clock:

  * makespan: Σ_hops max_w t[hop, w] — the straggler-bound superstep time a
    BSP deployment would see (the paper's Q3/Q4 straggler effect);
  * balance_eff: mean worker time / max worker time (load-balance component);
  * weak_eff: w=2-relative per-edge makespan throughput × balance
    (perfect weak scaling ⇒ flat makespan per edge);
  * exchange: measured boundary-message volume per query (halo ghosts on
    plain hops, boundary ETR rank summaries — cut edges — on ETR hops).
"""
from __future__ import annotations

import numpy as np

from repro.core import engine_partitioned as EP
from repro.graphdata.ldbc import LdbcParams, generate_ldbc
from repro.graphdata.partitioner import partition_graph
from repro.graphdata.queries import make_workload

from .common import SCALE, emit

BASE = {"ci": 50, "full": 125}[SCALE]


def run():
    workers = [2, 4, 8, 16]
    ref = None
    for w in workers:
        params = LdbcParams(n_persons=BASE * w, degree_dist="facebook", seed=3)
        g = generate_ldbc(params)
        part, arrays, _ = EP.partition_for(g, w, max(4, w // 2))
        wl = make_workload(g, templates=("Q1", "Q2", "Q4"), n_per_template=3,
                           seed=31)
        makespans, worker_time = [], np.zeros(w)
        msgs = 0
        for inst in wl:
            # repeats>1 takes the min per (hop, worker), excluding compile time
            prof = EP.measure_supersteps(g, inst.qry, n_workers=w, repeats=2)
            makespans.append(prof.makespan_s.sum())
            worker_time += prof.times_s.sum(axis=0)
            msgs += int(prof.exchange_msgs.sum())
        makespan = float(np.mean(makespans))           # s per query, measured
        balance_eff = float(worker_time.mean() / max(worker_time.max(), 1e-12))
        per_edge = makespan / max(g.n_edges, 1)
        if ref is None:
            ref = per_edge
        weak_eff = min(1.0, (ref / per_edge)) * balance_eff
        emit(f"weak_scaling/w{w}", makespan * 1e6,
             f"persons={BASE*w};balance_eff={balance_eff*100:.0f}%;"
             f"weak_eff={weak_eff*100:.0f}%;edge_cut={part.stats['edge_cut']*100:.1f}%;"
             f"xchg_msgs={msgs // len(wl)}")


def main():
    run()


if __name__ == "__main__":
    main()
