"""Fig 14 analogue: weak scaling — graph size ∝ worker count.

The container has one CPU, so wall-clock multi-node scaling cannot be
measured directly.  We report two honest quantities per (w, graph(w)):
  * makespan model: per-worker superstep work (typed-partition edge extents
    from the two-level partitioner) → efficiency = mean_work / max_work —
    the load-balance component of weak scaling (the paper's Q3/Q4 straggler
    effect shows up here);
  * measured single-stream execution time of the workload on graph(w),
    normalised by w (perfect weak scaling ⇒ flat).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine as E
from repro.graphdata.ldbc import LdbcParams, generate_ldbc
from repro.graphdata.partitioner import partition_graph
from repro.graphdata.queries import make_workload

from .common import SCALE, emit

BASE = {"ci": 50, "full": 125}[SCALE]


def run():
    workers = [2, 4, 8, 16]
    t_ref = None
    for w in workers:
        params = LdbcParams(n_persons=BASE * w, degree_dist="facebook", seed=3)
        g = generate_ldbc(params)
        p = partition_graph(g, n_workers=w, parts_per_type=max(4, w // 2))
        # per-worker edge work (messages owned by each worker's partitions)
        worker_edges = np.zeros(w)
        owner = p.worker_of_part[p.part_of]
        np.add.at(worker_edges, owner[g.e_dst], 1.0)
        balance_eff = worker_edges.mean() / max(worker_edges.max(), 1)
        wl = make_workload(g, templates=("Q1", "Q2", "Q4"), n_per_template=3,
                           seed=31)
        for inst in wl:
            E.count_results(g, inst.qry)  # warm
        t0 = time.perf_counter()
        for inst in wl:
            E.count_results(g, inst.qry)
        t = (time.perf_counter() - t0) / len(wl)
        if t_ref is None:
            t_ref, e_ref = t, g.n_edges
        # per-edge throughput relative to the w=2 point (flat = no super-
        # linear per-edge cost growth); the *distributed* weak-scaling
        # efficiency is this × the partition load balance (makespan model).
        tput_eff = min(1.0, (t_ref / t) * (g.n_edges / e_ref))
        eff = tput_eff * balance_eff
        emit(f"weak_scaling/w{w}", t * 1e6,
             f"persons={BASE*w};balance_eff={balance_eff*100:.0f}%;"
             f"weak_eff={eff*100:.0f}%;edge_cut={p.stats['edge_cut']*100:.1f}%")


def main():
    run()


if __name__ == "__main__":
    main()
