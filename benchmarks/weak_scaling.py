"""Fig 14 analogue: weak scaling — graph size ∝ worker count.

Unlike the seed (which only *modelled* per-worker makespan from partition
edge extents), this executes the PARTITIONED engine for real: each worker's
local superstep (halo gather → edge apply → local segment-sum delivery) is
run and timed separately (engine_partitioned.measure_supersteps), so the
reported quantities are measured wall-clock:

  * makespan: Σ_hops max_w t[hop, w] — the straggler-bound superstep time a
    BSP deployment would see (the paper's Q3/Q4 straggler effect);
  * balance_eff: mean worker time / max worker time (load-balance component);
  * weak_eff: w=2-relative per-edge makespan throughput × balance
    (perfect weak scaling ⇒ flat makespan per edge);
  * exchange: measured PER-CHANNEL boundary volume per query on the
    point-to-point lanes (state/extremum = halo ghosts, ETR = boundary rank
    summaries — cut edges), exactly the columns θ_net / θ_net_etr are fitted
    on (benchmarks/fit_cost_model) — keeping the cost model's accuracy claim
    checkable against the executor's real traffic.  The workload includes a
    MIN leg (queries.to_minmax) so the extremum channel is EXERCISED, not
    structurally zero — all three channels carry measured volume;
  * hop impl: the same representative superstep timed under both
    hop-delivery lowerings (xla materialize+segment_sum vs the fused
    hop_scatter kernel), reported as ``hop_makespan_ms`` per impl and the
    ``hop_speedup_pallas`` ratio the bench gate pins.

Writes ``BENCH_weak_scaling.json`` (per-worker-count rows); the CI bench
gate (scripts/check_bench.py) pins the structural exchange volumes exactly
and the efficiency/speedup ratios within a tolerance band.
"""
from __future__ import annotations

import json

import numpy as np

from repro.core import engine_partitioned as EP
from repro.graphdata.ldbc import LdbcParams, generate_ldbc
from repro.graphdata.queries import make_workload, to_minmax

from .common import SCALE, emit

BASE = {"ci": 50, "full": 125}[SCALE]
WORKERS = {"ci": (2, 4, 8), "full": (2, 4, 8, 16)}[SCALE]


def run(out_path: str = "BENCH_weak_scaling.json") -> dict:
    rows = []
    ref = None
    for w in WORKERS:
        params = LdbcParams(n_persons=BASE * w, degree_dist="facebook", seed=3)
        g = generate_ldbc(params)
        part, arrays, _ = EP.partition_for(g, w, max(4, w // 2))
        wl = make_workload(g, templates=("Q1", "Q2", "Q4"), n_per_template=3,
                           seed=31)
        # a MIN variant of a Q2 instance: the extremum channel carries real
        # boundary volume (without it the channel is structurally zero and
        # the gate on it is vacuous)
        q2 = next(i for i in wl if i.template == "Q2")
        wl = wl + [to_minmax(q2, g)]
        makespans, worker_time = [], np.zeros(w)
        channels = np.zeros(len(EP.CHANNELS), np.int64)
        for inst in wl:
            # repeats>1 takes the min per (hop, worker), excluding compile time
            prof = EP.measure_supersteps(g, inst.qry, n_workers=w, repeats=2)
            makespans.append(prof.makespan_s.sum())
            worker_time += prof.times_s.sum(axis=0)
            channels += prof.exchange_channels.sum(axis=0)
        makespan = float(np.mean(makespans))           # s per query, measured
        balance_eff = float(worker_time.mean() / max(worker_time.max(), 1e-12))
        per_edge = makespan / max(g.n_edges, 1)
        if ref is None:
            ref = per_edge
        weak_eff = min(1.0, (ref / per_edge)) * balance_eff
        xchg = {name: int(channels[i]) // len(wl)
                for i, name in enumerate(EP.CHANNELS)}
        # xla-vs-pallas hop timings: the same representative query's
        # supersteps under both delivery lowerings (bit-identical results;
        # what differs is the measured per-worker makespan)
        hop_ms = {}
        for impl in ("xla", "pallas"):
            prof_i = EP.measure_supersteps(g, q2.qry, n_workers=w, repeats=2,
                                           impl=impl)
            hop_ms[impl] = float(prof_i.makespan_s.sum()) * 1e3
        hop_speedup = hop_ms["xla"] / max(hop_ms["pallas"], 1e-12)
        rows.append(dict(
            n_workers=w,
            n_persons=BASE * w,
            n_edges=int(g.n_edges),
            makespan_s=makespan,
            balance_eff=balance_eff,
            weak_eff=weak_eff,
            edge_cut=float(part.stats["edge_cut"]),
            exchange_per_query=xchg,
            exchange_volume=arrays.exchange_volume(),
            etr_exchange_volume=arrays.etr_exchange_volume(),
            hop_makespan_ms=hop_ms,
            hop_speedup_pallas=hop_speedup,
        ))
        emit(f"weak_scaling/w{w}", makespan * 1e6,
             f"persons={BASE*w};balance_eff={balance_eff*100:.0f}%;"
             f"weak_eff={weak_eff*100:.0f}%;edge_cut={part.stats['edge_cut']*100:.1f}%;"
             f"xchg_state={xchg['state']};xchg_extremum={xchg['extremum']};"
             f"xchg_etr={xchg['etr']};hop_pallas={hop_speedup:.2f}x")
    report = dict(scale=SCALE, base_persons=BASE, rows=rows)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    return report


def main():
    run()


if __name__ == "__main__":
    main()
