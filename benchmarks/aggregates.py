"""Fig 12 analogue: temporal-aggregate query latency vs non-aggregate."""
from __future__ import annotations

import numpy as np

from repro.graphdata.ldbc import graph_name
from repro.graphdata.queries import make_workload
from repro.launch.query import GraniteServer

from .common import N_QUERIES, bench_graphs, emit, get_graph


def run():
    for params in bench_graphs(dists=("facebook",)):
        g = get_graph(params)
        name = graph_name(params)
        server = GraniteServer(g)
        wl_plain = make_workload(g, n_per_template=N_QUERIES, seed=41)
        wl_agg = make_workload(g, n_per_template=N_QUERIES, seed=41,
                               aggregate=True)
        r_plain = server.run_workload(wl_plain)
        r_agg = server.run_workload(wl_agg)
        by_t = {}
        for inst, rp in zip(wl_plain, r_plain):
            by_t.setdefault(inst.template, [[], []])[0].append(rp.latency_ms)
        for inst, ra in zip(wl_agg, r_agg):
            by_t.setdefault(inst.template, [[], []])[1].append(ra.latency_ms)
        for t, (pl, ag) in sorted(by_t.items()):
            if not pl or not ag:
                continue
            emit(f"aggregates/{name}/{t}", np.mean(ag) * 1e3,
                 f"plain_ms={np.mean(pl):.2f};agg_ms={np.mean(ag):.2f};"
                 f"overhead={np.mean(ag)/max(np.mean(pl),1e-9)*100-100:.0f}%")


def main():
    run()


if __name__ == "__main__":
    main()
