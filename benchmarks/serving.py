"""Serving benchmark (paper Table 5 analogue): the LDBC Q1–Q8 workload
through the batch-scheduler runtime vs the sequential per-query loop.

Three measurements, one JSON artifact (``BENCH_serving.json``):

  sequential   GraniteServer.run_workload — per-query latencies, drain
               throughput (the pre-serving baseline);
  batched      BatchScheduler drain — one vmapped call per shape group,
               drain throughput (the ≥2× acceptance number);
  open-loop    Poisson replay through the scheduler at a rate the sequential
               loop cannot sustain — p50/p95/p99 latency, throughput,
               completion-rate-within-budget; plus the same arrival schedule
               simulated against the sequential service times, showing what
               batching buys under load;
  partitioned  the same workload through the DISTRIBUTED engine's batched
               path (one partitioned traversal sweep per shape group), with
               the per-channel point-to-point exchange volumes the cost
               model's θ_net/θ_net_etr terms are fitted on — the numbers
               that keep the accuracy claim checkable.  (Correctness of the
               shard_map multi-device dispatch is pinned by the
               ``multidevice`` pytest leg; this bench reports the resolved
               device count it ran with.)
  slo          the SLO layer: online θ refit vs a static-θ baseline on the
               same dispatch trace (predicted-vs-measured error), an
               overload sweep (plain open loop vs deadline admission — the
               plain p99 diverges past the deadline, admission holds its
               admitted p99 inside it and reports reject/degrade counts and
               goodput), and a bounded closed-loop replay with per-query
               sampled deadlines.  BENCH_ENFORCE requires ≥80% of admitted
               queries inside their deadline (p99 ≤ 1.3× deadline — wall-
               clock slack; the exact 100% property is pinned on the virtual
               clock in tests/test_serving_slo.py), plain p99 > deadline,
               and a non-zero reject rate at 3× capacity; check_bench pins
               the rates/ratios.
  obs          flight-recorder overhead: the same warm drain with tracing +
               metrics attached vs the NullTracer default, compared on the
               MEASURED dispatch time (the timed region the telemetry and
               SLO layers consume — span construction happens outside it and
               must not leak in), plus an analytic bound on the disabled
               NullTracer path from its measured per-call cost.  Writes the
               traced run's span stream to ``BENCH_serving_trace.jsonl`` (the
               CI artifact scripts/trace_report.py renders) and asserts the
               traced results bit-identical to the untraced ones.
               check_bench pins traced_overhead ≤ 1.05 and null_overhead
               ≤ 1.01 as absolute (baseline-free) gates;
  ingest       live-graph serving: a slice of the graph's edges streams
               back in through the event log while the same workload drains
               after every epoch advance — latency-while-ingesting ratio vs
               the frozen drain, delta-executable dispatch count, cache
               invalidations at compaction, and bit-identity of the final
               epoch vs a from-scratch build.  BENCH_ENFORCE requires the
               ratio <= 3x and a non-zero delta dispatch count; check_bench
               pins the structural counters.
  chaos        fault-tolerant serving under a seeded FaultPlan: the workload
               drains three times on the partitioned engine with 10%
               transient dispatch faults, one injected worker loss (dense
               fallback → down window → probe restore), and one poison
               query — completion must be 100% (answer or structured
               quarantine, never an unhandled exception) and every answered
               query bit-identical to the fault-free reference; plus a
               crash/recover sub-leg (WAL write torn mid-line, recovery
               replays to the exact pre-crash epoch fingerprint).
               BENCH_ENFORCE requires completion_rate == 1.0,
               answers_identical, recovery_identical, and non-zero
               retry/quarantine counts; check_bench pins the counters and a
               goodput floor vs fault-free.
  hop_delivery xla-vs-pallas hop timings: ONE traversal-hop delivery
               (gather → mask → segment-reduce) timed as the
               materialize+segment_sum path and as the fused hop_scatter
               kernel, static and bucket mode, on a serving-scale graph
               (bit-identity asserted inside the measurement).  BENCH_ENFORCE
               requires a speedup on both legs; check_bench pins the ratios
               against the committed baselines.

Workload and arrivals are seeded → reproducible run-to-run; wall-clock
numbers vary with the host, ratios are the stable signal.  Compile time is
excluded (warm passes), as the paper excludes load time.
BENCH_ENFORCE=1 exits non-zero when batched drain throughput is under 2×
sequential (the ci.sh gate).
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

from repro.graphdata.ldbc import LdbcParams, generate_ldbc, graph_name
from repro.graphdata.queries import make_workload
from repro.launch.query import GraniteServer
from repro.serving import (AdmissionPolicy, BatchScheduler, PlanCache,
                           TelemetryBuffer, replay_workload)
from repro.serving.replay import poisson_arrivals

from .common import SCALE, emit, hop_delivery_times

SEED = 33
N_PER_TEMPLATE = {"ci": 8, "full": 50}[SCALE]
N_PERSONS = {"ci": 150, "full": 1000}[SCALE]
# the hop-delivery micro runs at production-ish edge counts (the regime the
# fused kernel targets), independent of the serving workload's graph size
HOP_N_PERSONS = {"ci": 1000, "full": 4000}[SCALE]
HOP_N_BUCKETS = 8
BUDGET_S = 600.0


def sequential_replay_sim(arrivals: np.ndarray, service_s: np.ndarray) -> dict:
    """FIFO simulation of the same open-loop arrivals against sequential
    per-query service times (no batching): the baseline's latency under
    load, from measured per-query costs."""
    t, lat = 0.0, []
    for arr, svc in zip(arrivals, service_s):
        t = max(t, float(arr)) + float(svc)
        lat.append(t - float(arr))
    lat_ms = np.asarray(lat) * 1e3
    return dict(
        latency_ms_p50=float(np.percentile(lat_ms, 50)),
        latency_ms_p95=float(np.percentile(lat_ms, 95)),
        latency_ms_p99=float(np.percentile(lat_ms, 99)),
        completion_rate=float(np.mean(lat_ms <= BUDGET_S * 1e3)),
        throughput_qps=len(lat) / max(t, 1e-12),
    )


def partitioned_leg(g, wl, seq_drain_s: float, n_workers: int = 4) -> dict:
    """Batched serving on the distributed engine + its exchange volumes
    (per channel, via the executor's canonical
    ``engine_partitioned.query_exchange_volumes``).

    The LDBC templates are plain counts, so a small same-shape MIN batch is
    appended to exercise (and report) the extremum channel — all three
    point-to-point channels show up in the artifact the bench gate pins."""
    from repro.core import engine_partitioned as EP
    from repro.core.engine_partitioned import query_exchange_volumes
    from repro.graphdata.queries import to_minmax

    wl_mm = [to_minmax(inst, g) for inst in
             make_workload(g, templates=("Q2",),
                           n_per_template=N_PER_TEMPLATE, seed=SEED + 1)]
    sched = BatchScheduler(g, engine="partitioned", n_workers=n_workers,
                           use_planner=True, budget_s=BUDGET_S)
    # two flushes so the vs-sequential ratio compares like with like: the
    # plain workload (what seq_drain_s measured) drains first, the MIN batch
    # separately (it exists to exercise the extremum channel, not the ratio)
    res = sched.run(wl, warm=True)
    drain_plain_s = sum(d.service_s for d in sched.last_dispatches)
    n_disp = len(sched.last_dispatches)
    res += sched.run(wl_mm, warm=True)
    drain_mm_s = sum(d.service_s for d in sched.last_dispatches)
    n_disp += len(sched.last_dispatches)
    assert all(r.ok for r in res)
    wl_all = list(wl) + wl_mm
    _, arrays, _ = EP.partition_for(g, n_workers)
    xchg = dict(state=0, extremum=0, etr=0)
    for inst in wl_all:
        for k, v in query_exchange_volumes(inst.qry, arrays).items():
            xchg[k] += v
    return dict(
        n_workers=n_workers,
        n_devices=sched.n_devices,
        n_queries=len(wl_all),
        drain_s=drain_plain_s + drain_mm_s,
        throughput_qps=len(wl_all) / max(drain_plain_s + drain_mm_s, 1e-12),
        throughput_vs_sequential=seq_drain_s / max(drain_plain_s, 1e-12),
        n_dispatches=n_disp,
        exchange_volumes=xchg,
        exchange_per_superstep=dict(
            state=arrays.exchange_volume(),
            etr=arrays.etr_exchange_volume(),
        ),
    )


def hop_delivery_leg() -> dict:
    """Per-impl hop-delivery timings (the fused-kernel acceptance number).

    Times the exact step the impl axis swaps — gather source state → apply
    the temporal edge mask → segment-reduce by arrival — on a dedicated
    serving-scale graph, in static and bucket mode.  The helper asserts
    bit-identity between the two paths before timing, so the reported
    speedup can never come from a diverged kernel."""
    from repro.core import superstep as SS

    g = generate_ldbc(LdbcParams(n_persons=HOP_N_PERSONS,
                                 degree_dist="facebook", seed=2))
    out = dict(n_persons=HOP_N_PERSONS, n_buckets=HOP_N_BUCKETS)
    for mode, name in ((SS.MODE_STATIC, "static"), (SS.MODE_BUCKET, "bucket")):
        out[name] = hop_delivery_times(g, mode, n_buckets=HOP_N_BUCKETS)
    return out


def slo_leg(g, wl, exec_cache, bat_drain_s: float, bat_tput: float,
            n_disp: int) -> dict:
    """The SLO serving experiment: online θ refit, deadline admission under
    overload, and bounded closed-loop replay.

    Three measurements (all on warm executables — the shared exec cache —
    so compile time never contaminates a virtual-clock latency):

      refit     the same dispatch trace recorded twice, once with the online
                θ refit and once as a static-θ baseline: the refit must
                shrink the tail predicted-vs-measured error (the paper's
                cost-model accuracy claim as a LIVE property);
      overload  open-loop replay at rates beyond batched capacity, with and
                without deadline admission: the plain queue's p99 diverges
                past the deadline while admission holds its ADMITTED p99
                inside it, trading rejects for goodput;
      closed    bounded-outstanding replay with per-query sampled deadlines:
                backlog (max dispatch batch) bounded by the slot count.

    Every knob self-scales from this run's measured batched cost per query,
    so the leg is meaningful on any host speed; check_bench pins the
    resulting rates/ratios against the committed baselines."""
    n = len(wl)
    c = bat_drain_s / n                       # measured batched s/query
    # a query can never finish faster than its own group's dispatch, so the
    # deadline scales from the measured PER-DISPATCH cost: ~3 dispatch times
    # is hittable when admission keeps waves short, and far below the
    # open-loop backlog at 3x capacity
    d_disp = bat_drain_s / max(n_disp, 1)
    deadline = 6.0 * d_disp
    refit_kw = dict(refit_every=8, min_samples=8, blend=0.7)

    def mk(telemetry=None, admission=None, planner_from=None):
        s = BatchScheduler(g, use_planner=True, budget_s=BUDGET_S,
                           plan_cache=PlanCache(), exec_cache=exec_cache,
                           telemetry=telemetry, admission=admission)
        if planner_from is not None:
            s._planner.coeffs.update(planner_from._planner.coeffs)
        return s

    # ---- online refit vs static θ on the same trace
    tb_online = TelemetryBuffer(**refit_kw)
    tb_static = TelemetryBuffer(refit=False)
    cal = mk(telemetry=tb_online)
    static = mk(telemetry=tb_static)
    for _ in range(4):
        cal.run(wl, warm=True)
        static.run(wl, warm=True)
    on_stats = tb_online.error_stats()
    off_stats = tb_static.error_stats()
    refit = dict(
        n_dispatches=on_stats["n"],
        n_refits=on_stats["n_refits"],
        online_tail_err=on_stats["tail_mean_abs_rel_err"],
        static_tail_err=off_stats["tail_mean_abs_rel_err"],
        improvement=off_stats["tail_mean_abs_rel_err"]
        / max(on_stats["tail_mean_abs_rel_err"], 1e-9),
    )

    # ---- overload sweep: plain open loop vs deadline admission, same trace.
    # The workload repeats 3x so the open-loop backlog has room to diverge
    # well past the deadline (all shapes stay cached — no new compiles).
    # Headroom 0.25 bounds each admitted wave to ~1 predicted dispatch:
    # per-dispatch timings at the ~1ms scale carry up to ~2x measurement
    # noise, and a query can queue one full wave before it is even
    # submitted, so the structural margin has to absorb both.
    wl_ov = list(wl) * 3
    policy = AdmissionPolicy(headroom=0.25, degrade_impls=(),
                             allow_engine_downgrade=False)

    def admitted_p99(rep) -> float:
        lat = rep.latencies_ms[[i for i, s in enumerate(rep.statuses)
                                if s == "done"]]
        return float(np.percentile(lat, 99)) if lat.size else 0.0

    sweep = []
    for mult in (1.5, 3.0):
        rate = mult * bat_tput
        plain = replay_workload(mk(), wl_ov, rate_qps=rate, seed=SEED,
                                warm=True, deadline_s=deadline)
        slo_s = mk(telemetry=TelemetryBuffer(**refit_kw), admission=policy,
                   planner_from=cal)           # start from the refitted θ
        adm = replay_workload(slo_s, wl_ov, rate_qps=rate, seed=SEED,
                              warm=True, deadline_s=deadline)
        sweep.append(dict(
            rate_mult=mult, rate_qps=rate,
            plain_hit_rate=plain.deadline_hit_rate,
            plain_p99_ms=plain.latency_ms_p99,
            admitted_hit_rate=(
                float(np.mean(adm.latencies_ms[
                    [i for i, s in enumerate(adm.statuses) if s == "done"]]
                    <= deadline * 1e3)) if adm.n_completed else 0.0),
            admitted_p99_ms=admitted_p99(adm),
            deadline_hit_rate=adm.deadline_hit_rate,
            reject_rate=adm.reject_rate,
            n_degraded=adm.n_degraded,
            goodput_qps=adm.goodput_qps,
            plain_goodput_qps=plain.goodput_qps,
        ))
    top = sweep[-1]
    overload = dict(
        deadline_ms=deadline * 1e3,
        rate_qps=top["rate_qps"],
        admitted_hit_rate=top["admitted_hit_rate"],
        admitted_p99_ms=top["admitted_p99_ms"],
        plain_p99_ms=top["plain_p99_ms"],
        divergence=top["plain_p99_ms"] / max(top["admitted_p99_ms"], 1e-9),
        reject_rate=top["reject_rate"],
        goodput_qps=top["goodput_qps"],
        plain_goodput_qps=top["plain_goodput_qps"],
    )

    # ---- bounded closed loop with per-query sampled deadlines
    closed_rep = replay_workload(mk(), wl, mode="closed", max_outstanding=8,
                                 seed=SEED, warm=True,
                                 deadline_s=(4.0 * c, 12.0 * c))
    closed = dict(
        max_outstanding=closed_rep.max_outstanding,
        max_batch=closed_rep.max_batch,
        n_dispatches=closed_rep.n_dispatches,
        completion_rate=closed_rep.completion_rate,
        deadline_hit_rate=closed_rep.deadline_hit_rate,
        latency_ms_p99=closed_rep.latency_ms_p99,
    )
    return dict(deadline_ms=deadline * 1e3, refit=refit, sweep=sweep,
                overload=overload, closed=closed)


def obs_leg(g, wl, exec_cache,
            trace_path: str = "BENCH_serving_trace.jsonl") -> dict:
    """Flight-recorder overhead leg + the trace artifact.

    Overhead is compared on the MEASURED dispatch time: that is the timed
    region everything downstream trusts (telemetry rows, SLO admission, the
    cost-model audit), and span/metric bookkeeping happens strictly outside
    it — so the traced ratio gates instrumentation leaking INTO the hot
    path, not the cost of recording itself.  At ~1 ms dispatch scale on a
    shared single-core box the measurement needs four noise controls:

    * the comparison runs on an 8× replication of the workload — same
      shape groups, 8× batches — so each timed region is tens of ms and
      the fixed cache-rewarm cost after any bookkeeping amortises away;
    * within a drain, the drain is deterministic so each repeat dispatches
      the same unit sequence and the PER-DISPATCH minimum across repeats
      (GC quiesced) filters pauses landing inside one repeat's timed region;
    * the first dispatch of a flush is excluded: it absorbs the cross-flush
      cache boundary (for a traced run, the previous flush's deferred span
      emission — outside every timed region, but it still evicts the caches
      the next JAX call re-warms), which the per-dispatch min cannot filter
      because it recurs in every repeat;
    * plain and traced drains alternate in ROUNDS and the gate compares
      best-round vs best-round (min-vs-min, the standard noise-immune
      statistic) — host-noise bursts only ever inflate a round, while a
      real hot-path leak sits in every round including the best.

    The trace artifact + bit-identity check run on the ORIGINAL workload
    with the JSONL sink attached, keeping the uploaded artifact one
    drain's spans rather than the whole measurement matrix.  The
    NullTracer number is an analytic bound: its measured per-call no-op
    cost scaled by the instrumentation call count of one drain (there is
    no un-instrumented build left to diff against)."""
    import gc
    import time

    from repro.obs import MetricsRegistry, Tracer
    from repro.obs.trace import NULL_TRACER

    rounds, repeats = 5, 3
    wl_big = list(wl) * 8

    def drain(workload, tracer=None, metrics=None):
        sched = BatchScheduler(g, use_planner=True, budget_s=BUDGET_S,
                               plan_cache=PlanCache(), exec_cache=exec_cache,
                               tracer=tracer, metrics=metrics)
        res = sched.run(workload, warm=True)    # results + warm plan cache
        best = None
        gc.collect()
        gc.disable()
        try:
            for _ in range(repeats):
                sched.run(workload, warm=True)
                times = [d.service_s for d in sched.last_dispatches]
                best = (times if best is None
                        else [min(a, b) for a, b in zip(best, times)])
        finally:
            gc.enable()
        steady = best[1:] if len(best) > 1 else best
        return res, sum(steady), sched

    t_plains, t_traceds = [], []
    for _ in range(rounds):
        _, tp, _ = drain(wl_big)
        _, tt, _ = drain(wl_big, tracer=Tracer(), metrics=MetricsRegistry())
        t_plains.append(tp)
        t_traceds.append(tt)
    t_plain = min(t_plains)
    t_traced = min(t_traceds)
    ratio = t_traced / max(t_plain, 1e-12)

    # artifact drain on the original workload: the uploaded trace JSONL +
    # the traced-vs-untraced bit-identity assertion
    res_plain, _, _ = drain(wl)
    tracer = Tracer(sink=trace_path)
    res_traced, _, _ = drain(wl, tracer=tracer, metrics=MetricsRegistry())
    tracer.close()
    for a, b in zip(res_plain, res_traced):
        assert a.count == b.count and a.ok == b.ok, \
            ("traced run diverged", a, b)

    # disabled-path bound: one no-op start+end per query is what the
    # un-guarded instrumentation sites cost when tracing is off
    n_calls = 200_000
    t0 = time.perf_counter()
    for _ in range(n_calls):
        NULL_TRACER.start("x")
        NULL_TRACER.end(None)
    per_call_s = (time.perf_counter() - t0) / (2 * n_calls)
    calls_per_drain = 2 * len(wl_big) + 16      # submit+flush sites, rounded
    null_overhead = 1.0 + calls_per_drain * per_call_s / max(t_plain, 1e-12)

    return dict(
        n_queries=len(wl_big),
        rounds=rounds,
        repeats=repeats,
        untraced_dispatch_s=t_plain,
        traced_dispatch_s=t_traced,
        traced_overhead=ratio,
        null_call_ns=per_call_s * 1e9,
        null_calls_per_drain=calls_per_drain,
        null_overhead=null_overhead,
        n_spans=tracer.n_completed,
        bit_identical=True,
        trace_path=trace_path,
    )


def dynamic_leg() -> dict:
    """Secondary measurement on the dynamic graph (bucket mode): per-query
    compute carries a ×n_buckets state, so vmap amortises a smaller overhead
    fraction — reported, not enforced."""
    params = LdbcParams(n_persons=N_PERSONS, degree_dist="facebook",
                        dynamic=True, seed=2)
    g = generate_ldbc(params)
    wl = make_workload(g, n_per_template=N_PER_TEMPLATE, seed=SEED)
    server = GraniteServer(g, use_planner=True, budget_s=BUDGET_S)
    seq_s = sum(r.latency_ms for r in server.run_workload(wl)) / 1e3
    sched = BatchScheduler(g, use_planner=True, budget_s=BUDGET_S)
    sched.run(wl, warm=True)
    bat_s = sum(d.service_s for d in sched.last_dispatches)
    return dict(graph=graph_name(params), n_queries=len(wl),
                drain_seq_s=seq_s, drain_batched_s=bat_s,
                throughput_ratio=seq_s / max(bat_s, 1e-12))


def ingest_leg(g) -> dict:
    """Live-graph serving: latency while ingestion advances epoch-pinned
    snapshots vs the same warm drain on a frozen graph.

    A slice of ``g``'s edges is held out, the rest becomes epoch 0 of an
    event log; the held-out edges stream back in across epochs while the
    same workload drains after every ``advance``.  Reported:

      latency_ratio          mean per-epoch live drain / frozen drain — the
                             price of serving during ingestion (delta
                             executables and base-fingerprint plans stay
                             warm, so the band is tight; check_bench pins
                             an absolute ceiling);
      delta_exec_dispatches  groups served by the base+delta executable
                             (must be > 0 — the delta path is exercised);
      frozen_identical       final-epoch answers bit-identical to a fresh
                             scheduler on a from-scratch build of the final
                             graph (asserted here, pinned exactly);
      invalidations          cache entries evicted by the closing
                             compaction (delta-aware: zero during the pure
                             edge-append epochs).
    """
    from repro.graphdata import ingest
    from repro.obs import MetricsRegistry
    from repro.serving import EpochManager

    n_epochs = 3                       # edge-append epochs before compaction
    holdout = max(3 * n_epochs, g.n_edges // 20)
    log, held = ingest.log_from_graph(g, holdout_edges=holdout, seed=SEED)
    per = len(held) // n_epochs
    chunks = [held[i * per:(i + 1) * per] for i in range(n_epochs - 1)]
    chunks.append(held[(n_epochs - 1) * per:])

    mx = MetricsRegistry()
    mgr = EpochManager(log, compact_every=2 * n_epochs, metrics=mx)
    e0 = mgr.seal()
    wl = make_workload(e0.graph, n_per_template=N_PER_TEMPLATE, seed=SEED)
    live = BatchScheduler(e0.graph, use_planner=True, budget_s=BUDGET_S,
                          metrics=mx)
    mgr.attach(live)
    live.run(wl, warm=True)

    frozen_sched = BatchScheduler(e0.graph, use_planner=True,
                                  budget_s=BUDGET_S)
    frozen_sched.run(wl, warm=True)
    frozen_sched.run(wl, warm=True)
    frozen_s = sum(d.service_s for d in frozen_sched.last_dispatches)

    live_s, n_delta, ok = [], 0, True
    for chunk in chunks:
        mgr.ingest(chunk)
        mgr.advance(live)
        res = live.run(wl, warm=True)
        ok = ok and all(r.ok for r in res)
        live_s.append(sum(d.service_s for d in live.last_dispatches))
        n_delta += sum(1 for d in live.last_dispatches if d.delta)
    mgr.advance(live, compact=True)
    res = live.run(wl, warm=True)
    ok = ok and all(r.ok for r in res)

    ref = BatchScheduler(ingest.materialize(log, log.n_epochs),
                         use_planner=True, budget_s=BUDGET_S).run(wl)
    frozen_identical = all(a.count == b.count for a, b in zip(res, ref))
    assert frozen_identical, "live serving diverged from a from-scratch build"

    cache = mx.counter("granite_cache_total", "serving cache events",
                       labelnames=("cache", "event"))
    ratio = float(np.mean(live_s)) / max(frozen_s, 1e-12)
    return dict(
        n_queries=len(wl),
        n_held_edges=len(held),
        n_epochs=mgr.current.id + 1,
        n_compactions=mgr.n_compactions,
        frozen_drain_s=frozen_s,
        live_drain_s_mean=float(np.mean(live_s)),
        latency_ratio=ratio,
        delta_exec_dispatches=n_delta,
        frozen_identical=frozen_identical,
        completion_rate=float(ok),
        exec_invalidations=cache.value(cache="executable",
                                       event="invalidation"),
        plan_invalidations=cache.value(cache="plan", event="invalidation"),
    )


def chaos_leg(g, wl, n_workers: int = 4,
              wal_path: str = "BENCH_chaos_wal.jsonl") -> dict:
    """Fault-tolerant serving under a seeded FaultPlan (the paper's
    completion claim as a measured property).

    The workload drains three times on the partitioned engine against a
    fault-free reference:

      flush 1   10% seeded transient dispatch faults + the FIRST partitioned
                dispatch loses a worker → the whole flush re-plans dense;
      flush 2   the partitioned path is still inside its down window
                (``probe_after``) → dense again, no worker consultations;
      flush 3   the probe dispatch fires, succeeds, and restores the
                partitioned path.

    One query is poisoned (fails deterministically): bisection isolates it
    and quarantines exactly that query each flush, everything else answers.
    Reported/enforced: completion rate (answer-or-structured-reject — must
    be 1.0), bit-identity of every answered query vs the reference,
    retry/quarantine/fallback counts, and goodput vs fault-free (retry
    backoff is ACCOUNTED into the drain, so the ratio prices the faults).

    The crash/recover sub-leg tears a WAL append mid-line (simulated crash
    mid-ingest) and requires ``EpochManager.recover`` to restore the exact
    pre-crash pinned-epoch fingerprint."""
    from repro.graphdata import ingest
    from repro.serving import (EpochManager, FaultPlan, RetryPolicy,
                               TornWriteError)

    ref = BatchScheduler(g, engine="partitioned", n_workers=n_workers,
                         use_planner=True, budget_s=BUDGET_S)
    ref.run(wl, warm=True)
    ref_res = ref.run(wl, warm=True)            # warm reference drain
    ref_drain = sum(d.service_s for d in ref.last_dispatches)
    assert all(r.ok for r in ref_res)

    poison = wl[len(wl) // 2].qry
    plan = FaultPlan(seed=SEED, rates={"dispatch": 0.10},
                     schedule={"worker": {0}},
                     poison=lambda q: q is poison)
    sched = BatchScheduler(g, engine="partitioned", n_workers=n_workers,
                           use_planner=True, budget_s=BUDGET_S,
                           plan_cache=ref.plan_cache,
                           exec_cache=ref.exec_cache,
                           fault_plan=plan, retry=RetryPolicy(seed=SEED))
    flushes, drains, engines = [], [], []
    for _ in range(3):
        res = sched.run(wl, warm=True)
        flushes.append(res)
        drains.append(sum(d.service_s for d in sched.last_dispatches))
        engines.append(sorted({r.engine for r in res if r.status == "done"}))
    n_total = 3 * len(wl)
    n_done = n_quar = 0
    identical = True
    for res in flushes:
        for r, rr in zip(res, ref_res):
            if r.status == "done":
                n_done += 1
                identical = identical and r.count == rr.count
            elif r.status == "quarantined":
                n_quar += 1
    completion_rate = (n_done + n_quar) / n_total
    rep = sched.fault_report()
    # goodput prices the chaos: answered queries per accounted second vs the
    # fault-free drain (backoff penalties and retried dispatches inflate
    # the denominator)
    goodput_ratio = ((n_done / max(sum(drains), 1e-12))
                     / (len(wl) / max(ref_drain, 1e-12)))

    # ---- crash/recover: tear a WAL append mid-line, then recover
    log, held = ingest.log_from_graph(g, holdout_edges=30, seed=SEED)
    log.attach_wal(wal_path,
                   fault_plan=FaultPlan(seed=SEED, schedule={"wal": {15}}))
    mgr = EpochManager(log)
    mgr.seal()                                  # epoch 0 (no WAL consults)
    mgr.ingest(held[:10])
    mgr.seal()                                  # epoch 1
    pre_fp = mgr.current.fingerprint
    torn = False
    try:
        mgr.ingest(held[10:])                   # k=15 tears mid-batch
    except TornWriteError:
        torn = True
    del mgr                                     # the crash
    mgr2 = EpochManager.recover(wal_path)
    recovery_identical = torn and mgr2.current.fingerprint == pre_fp
    assert recovery_identical, "WAL recovery diverged from pre-crash state"
    mgr2.log.close_wal()

    return dict(
        n_queries=len(wl),
        n_flushes=3,
        n_done=n_done,
        completion_rate=completion_rate,
        answers_identical=bool(identical),
        n_retries=rep["n_retries"],
        n_quarantined=rep["n_quarantined"],
        n_timeout=rep["n_timeout"],
        n_fallbacks=rep["n_fallbacks"],
        partitioned_restored=bool(rep["partitioned_available"]),
        engines_per_flush=engines,
        fault_plan=rep["fault_plan"],
        ref_drain_s=ref_drain,
        chaos_drain_s=float(sum(drains)),
        goodput_ratio=float(goodput_ratio),
        recovery=dict(
            recovery_identical=bool(recovery_identical),
            n_recovered_epochs=mgr2.log.n_epochs,
            n_open_survivors=mgr2.log.n_open,
        ),
    )


def run(out_path: str = "BENCH_serving.json") -> dict:
    # the hop micro runs FIRST: it times a single kernel-vs-scatter step, so
    # it must not inherit the heap/caches the workload legs accumulate
    hop = hop_delivery_leg()
    params = LdbcParams(n_persons=N_PERSONS, degree_dist="facebook",
                        dynamic=False, seed=2)
    g = generate_ldbc(params)
    wl = make_workload(g, n_per_template=N_PER_TEMPLATE, seed=SEED)
    n = len(wl)
    print(f"# serving: {graph_name(params)} — {n} queries "
          f"({N_PER_TEMPLATE}/template), seed={SEED}", flush=True)

    # ---- sequential baseline (run_workload warms per instance first)
    server = GraniteServer(g, use_planner=True, budget_s=BUDGET_S)
    seq_recs = server.run_workload(wl)
    seq_ms = np.asarray([r.latency_ms for r in seq_recs])
    seq_drain_s = float(seq_ms.sum()) / 1e3
    seq_tput = n / max(seq_drain_s, 1e-12)

    # ---- batched drain through the scheduler (warm dispatches)
    sched = BatchScheduler(g, use_planner=True, budget_s=BUDGET_S)
    bat_res = sched.run(wl, warm=True)
    bat_drain_s = sum(d.service_s for d in sched.last_dispatches)
    bat_tput = n / max(bat_drain_s, 1e-12)
    for a, b in zip(seq_recs, bat_res):
        assert a.count == b.count, (a.template, a.count, b.count)
    ratio = bat_tput / seq_tput

    # ---- open-loop replay at a rate the sequential loop cannot sustain
    rate = 2.0 * seq_tput
    replay_sched = BatchScheduler(g, use_planner=True, budget_s=BUDGET_S,
                                  plan_cache=sched.plan_cache,
                                  exec_cache=sched.exec_cache)
    rep = replay_workload(replay_sched, wl, rate_qps=rate, seed=SEED,
                          budget_s=BUDGET_S, warm=True)
    seq_sim = sequential_replay_sim(
        poisson_arrivals(n, rate, np.random.default_rng(SEED)), seq_ms / 1e3)

    # ---- SLO layer: online refit, overload admission sweep, closed loop
    slo = slo_leg(g, wl, sched.exec_cache, bat_drain_s, bat_tput,
                  len(sched.last_dispatches))

    # ---- flight-recorder overhead + trace artifact
    obs = obs_leg(g, wl, sched.exec_cache)

    # ---- live-graph serving: epoch-pinned drains while ingesting
    ing = ingest_leg(g)

    # ---- fault-tolerant serving under a seeded FaultPlan + crash recovery
    chaos = chaos_leg(g, wl)

    report = dict(
        graph=graph_name(params),
        scale=SCALE,
        seed=SEED,
        n_queries=n,
        budget_s=BUDGET_S,
        sequential=dict(
            drain_s=seq_drain_s,
            throughput_qps=seq_tput,
            latency_ms_p50=float(np.percentile(seq_ms, 50)),
            latency_ms_p95=float(np.percentile(seq_ms, 95)),
            latency_ms_p99=float(np.percentile(seq_ms, 99)),
            completion_rate=float(np.mean([r.ok for r in seq_recs])),
        ),
        batched=dict(
            drain_s=bat_drain_s,
            throughput_qps=bat_tput,
            n_dispatches=len(sched.last_dispatches),
            mean_batch=float(np.mean(
                [d.n_real for d in sched.last_dispatches])),
            caches=sched.cache_report(),
        ),
        throughput_ratio=ratio,
        replay=rep.as_dict(),
        replay_sequential_sim=seq_sim,
        slo=slo,
        obs=obs,
        partitioned=partitioned_leg(g, wl, seq_drain_s),
        dynamic_leg=dynamic_leg(),
        hop_delivery=hop,
        ingest=ing,
        chaos=chaos,
    )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    # emit()'s value column is µs-per-call: per-query drain cost here
    emit("serving/drain_seq_us_per_query", seq_drain_s / n * 1e6, f"n={n}")
    emit("serving/drain_batched_us_per_query", bat_drain_s / n * 1e6,
         f"ratio={ratio:.2f}x;dispatches={len(sched.last_dispatches)}")
    emit("serving/replay_p95_us", rep.latency_ms_p95 * 1e3,
         f"rate={rate:.1f}qps;completion={rep.completion_rate:.3f};"
         f"seq_sim_p95_ms={seq_sim['latency_ms_p95']:.1f}")
    emit("serving/hop_delivery_bucket_us", hop["bucket"]["pallas_ms"] * 1e3,
         f"speedup={hop['bucket']['speedup']:.2f}x;"
         f"static_speedup={hop['static']['speedup']:.2f}x;"
         f"edges={hop['bucket']['edges']}")
    emit("serving/slo_admitted_p99_us", slo["overload"]["admitted_p99_ms"]
         * 1e3,
         f"hit={slo['overload']['admitted_hit_rate']:.3f};"
         f"reject={slo['overload']['reject_rate']:.3f};"
         f"plain_p99_ms={slo['overload']['plain_p99_ms']:.1f};"
         f"refit_err={slo['refit']['online_tail_err']:.3f}"
         f"(static {slo['refit']['static_tail_err']:.3f})")
    emit("serving/obs_traced_dispatch_us_per_query",
         obs["traced_dispatch_s"] / obs["n_queries"] * 1e6,
         f"overhead={obs['traced_overhead']:.3f}x;"
         f"null={obs['null_overhead']:.4f}x;spans={obs['n_spans']}")
    emit("serving/ingest_live_drain_us_per_query",
         ing["live_drain_s_mean"] / ing["n_queries"] * 1e6,
         f"ratio={ing['latency_ratio']:.2f}x;"
         f"delta_dispatches={ing['delta_exec_dispatches']};"
         f"epochs={ing['n_epochs']};"
         f"invalidations={ing['exec_invalidations']:.0f}")
    emit("serving/chaos_drain_us_per_query",
         chaos["chaos_drain_s"] / (3 * chaos["n_queries"]) * 1e6,
         f"completion={chaos['completion_rate']:.3f};"
         f"goodput={chaos['goodput_ratio']:.2f}x;"
         f"retries={chaos['n_retries']};"
         f"quarantined={chaos['n_quarantined']};"
         f"recovered={chaos['recovery']['n_recovered_epochs']}ep")
    print(f"# batched drain throughput {bat_tput:.1f} qps vs sequential "
          f"{seq_tput:.1f} qps → {ratio:.2f}x", flush=True)
    print(f"# fused hop kernel: static {hop['static']['speedup']:.2f}x, "
          f"bucket {hop['bucket']['speedup']:.2f}x vs materialize+segment_sum",
          flush=True)
    print(f"# wrote {out_path}", flush=True)
    if os.environ.get("BENCH_ENFORCE") == "1":
        if ratio < 2.0:
            print(f"# FAIL: throughput ratio {ratio:.2f}x < 2x", flush=True)
            sys.exit(1)
        # the fused-kernel acceptance floor: a real measured hop-delivery
        # speedup on both legs (thresholds leave slack for host jitter;
        # typical measured values are ~3-6x static, ~1.5-1.8x bucket)
        if hop["static"]["speedup"] < 1.5 or hop["bucket"]["speedup"] < 1.1:
            print(f"# FAIL: fused hop speedup static "
                  f"{hop['static']['speedup']:.2f}x (<1.5) or bucket "
                  f"{hop['bucket']['speedup']:.2f}x (<1.1)", flush=True)
            sys.exit(1)
        # SLO acceptance: at 3x capacity the plain open loop must blow past
        # the deadline while admission holds its admitted queries inside
        # theirs.  The EXACT property (100% of admitted inside the deadline
        # under consistent predictions) is pinned deterministically by
        # tests/test_serving_slo.py on the virtual clock; here dispatches
        # are ~1ms wall-time measurements, so the floor tolerates host
        # jitter: >=80% of admitted hit, p99 within 1.3x of the deadline —
        # still far under the plain open loop's 2.5-4x divergence.
        ov = slo["overload"]
        if ov["admitted_hit_rate"] < 0.8:
            print(f"# FAIL: admitted deadline-hit rate "
                  f"{ov['admitted_hit_rate']:.3f} < 0.8", flush=True)
            sys.exit(1)
        if ov["admitted_p99_ms"] > 1.3 * ov["deadline_ms"]:
            print(f"# FAIL: admitted p99 {ov['admitted_p99_ms']:.1f}ms over "
                  f"1.3x deadline {ov['deadline_ms']:.1f}ms", flush=True)
            sys.exit(1)
        if ov["plain_p99_ms"] <= ov["deadline_ms"]:
            print(f"# FAIL: plain open loop did not diverge "
                  f"(p99 {ov['plain_p99_ms']:.1f}ms <= deadline "
                  f"{ov['deadline_ms']:.1f}ms) — overload rate too low",
                  flush=True)
            sys.exit(1)
        if not ov["reject_rate"] > 0:
            print("# FAIL: admission rejected nothing under 3x overload",
                  flush=True)
            sys.exit(1)
        # live-graph acceptance: serving while ingesting must stay within
        # 3x of the frozen drain (warm delta executables keep it near 1x;
        # the headroom absorbs merged-graph groups re-warming per epoch)
        # and the delta-executable path must actually have been used
        if ing["latency_ratio"] > 3.0:
            print(f"# FAIL: live-serving latency ratio "
                  f"{ing['latency_ratio']:.2f}x > 3x frozen", flush=True)
            sys.exit(1)
        if not ing["delta_exec_dispatches"] > 0:
            print("# FAIL: no group was served by the delta executable",
                  flush=True)
            sys.exit(1)
        # chaos acceptance: the completion claim is EXACT — every query
        # under the seeded FaultPlan answers or is structurally rejected,
        # answered queries are bit-identical to fault-free, and crash
        # recovery restores the exact pre-crash epoch fingerprint
        if chaos["completion_rate"] != 1.0:
            print(f"# FAIL: chaos completion rate "
                  f"{chaos['completion_rate']:.4f} != 1.0", flush=True)
            sys.exit(1)
        if not chaos["answers_identical"]:
            print("# FAIL: a fault-injected answer diverged from the "
                  "fault-free reference", flush=True)
            sys.exit(1)
        if not chaos["recovery"]["recovery_identical"]:
            print("# FAIL: WAL crash recovery diverged from the pre-crash "
                  "epoch fingerprint", flush=True)
            sys.exit(1)
        if not (chaos["n_retries"] > 0 and chaos["n_quarantined"] > 0
                and chaos["n_fallbacks"] > 0):
            print(f"# FAIL: chaos exercised nothing "
                  f"(retries={chaos['n_retries']}, "
                  f"quarantined={chaos['n_quarantined']}, "
                  f"fallbacks={chaos['n_fallbacks']})", flush=True)
            sys.exit(1)
        if not chaos["partitioned_restored"]:
            print("# FAIL: partitioned path never restored after the probe "
                  "window", flush=True)
            sys.exit(1)
    return report


def main():
    run()


if __name__ == "__main__":
    main()
