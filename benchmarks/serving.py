"""Serving benchmark (paper Table 5 analogue): the LDBC Q1–Q8 workload
through the batch-scheduler runtime vs the sequential per-query loop.

Three measurements, one JSON artifact (``BENCH_serving.json``):

  sequential   GraniteServer.run_workload — per-query latencies, drain
               throughput (the pre-serving baseline);
  batched      BatchScheduler drain — one vmapped call per shape group,
               drain throughput (the ≥2× acceptance number);
  open-loop    Poisson replay through the scheduler at a rate the sequential
               loop cannot sustain — p50/p95/p99 latency, throughput,
               completion-rate-within-budget; plus the same arrival schedule
               simulated against the sequential service times, showing what
               batching buys under load;
  partitioned  the same workload through the DISTRIBUTED engine's batched
               path (one partitioned traversal sweep per shape group), with
               the per-channel point-to-point exchange volumes the cost
               model's θ_net/θ_net_etr terms are fitted on — the numbers
               that keep the accuracy claim checkable.  (Correctness of the
               shard_map multi-device dispatch is pinned by the
               ``multidevice`` pytest leg; this bench reports the resolved
               device count it ran with.)
  hop_delivery xla-vs-pallas hop timings: ONE traversal-hop delivery
               (gather → mask → segment-reduce) timed as the
               materialize+segment_sum path and as the fused hop_scatter
               kernel, static and bucket mode, on a serving-scale graph
               (bit-identity asserted inside the measurement).  BENCH_ENFORCE
               requires a speedup on both legs; check_bench pins the ratios
               against the committed baselines.

Workload and arrivals are seeded → reproducible run-to-run; wall-clock
numbers vary with the host, ratios are the stable signal.  Compile time is
excluded (warm passes), as the paper excludes load time.
BENCH_ENFORCE=1 exits non-zero when batched drain throughput is under 2×
sequential (the ci.sh gate).
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

from repro.graphdata.ldbc import LdbcParams, generate_ldbc, graph_name
from repro.graphdata.queries import make_workload
from repro.launch.query import GraniteServer
from repro.serving import BatchScheduler, replay_workload
from repro.serving.replay import poisson_arrivals

from .common import SCALE, emit, hop_delivery_times

SEED = 33
N_PER_TEMPLATE = {"ci": 8, "full": 50}[SCALE]
N_PERSONS = {"ci": 150, "full": 1000}[SCALE]
# the hop-delivery micro runs at production-ish edge counts (the regime the
# fused kernel targets), independent of the serving workload's graph size
HOP_N_PERSONS = {"ci": 1000, "full": 4000}[SCALE]
HOP_N_BUCKETS = 8
BUDGET_S = 600.0


def sequential_replay_sim(arrivals: np.ndarray, service_s: np.ndarray) -> dict:
    """FIFO simulation of the same open-loop arrivals against sequential
    per-query service times (no batching): the baseline's latency under
    load, from measured per-query costs."""
    t, lat = 0.0, []
    for arr, svc in zip(arrivals, service_s):
        t = max(t, float(arr)) + float(svc)
        lat.append(t - float(arr))
    lat_ms = np.asarray(lat) * 1e3
    return dict(
        latency_ms_p50=float(np.percentile(lat_ms, 50)),
        latency_ms_p95=float(np.percentile(lat_ms, 95)),
        latency_ms_p99=float(np.percentile(lat_ms, 99)),
        completion_rate=float(np.mean(lat_ms <= BUDGET_S * 1e3)),
        throughput_qps=len(lat) / max(t, 1e-12),
    )


def partitioned_leg(g, wl, seq_drain_s: float, n_workers: int = 4) -> dict:
    """Batched serving on the distributed engine + its exchange volumes
    (per channel, via the executor's canonical
    ``engine_partitioned.query_exchange_volumes``).

    The LDBC templates are plain counts, so a small same-shape MIN batch is
    appended to exercise (and report) the extremum channel — all three
    point-to-point channels show up in the artifact the bench gate pins."""
    from repro.core import engine_partitioned as EP
    from repro.core.engine_partitioned import query_exchange_volumes
    from repro.graphdata.queries import to_minmax

    wl_mm = [to_minmax(inst, g) for inst in
             make_workload(g, templates=("Q2",),
                           n_per_template=N_PER_TEMPLATE, seed=SEED + 1)]
    sched = BatchScheduler(g, engine="partitioned", n_workers=n_workers,
                           use_planner=True, budget_s=BUDGET_S)
    # two flushes so the vs-sequential ratio compares like with like: the
    # plain workload (what seq_drain_s measured) drains first, the MIN batch
    # separately (it exists to exercise the extremum channel, not the ratio)
    res = sched.run(wl, warm=True)
    drain_plain_s = sum(d.service_s for d in sched.last_dispatches)
    n_disp = len(sched.last_dispatches)
    res += sched.run(wl_mm, warm=True)
    drain_mm_s = sum(d.service_s for d in sched.last_dispatches)
    n_disp += len(sched.last_dispatches)
    assert all(r.ok for r in res)
    wl_all = list(wl) + wl_mm
    _, arrays, _ = EP.partition_for(g, n_workers)
    xchg = dict(state=0, extremum=0, etr=0)
    for inst in wl_all:
        for k, v in query_exchange_volumes(inst.qry, arrays).items():
            xchg[k] += v
    return dict(
        n_workers=n_workers,
        n_devices=sched.n_devices,
        n_queries=len(wl_all),
        drain_s=drain_plain_s + drain_mm_s,
        throughput_qps=len(wl_all) / max(drain_plain_s + drain_mm_s, 1e-12),
        throughput_vs_sequential=seq_drain_s / max(drain_plain_s, 1e-12),
        n_dispatches=n_disp,
        exchange_volumes=xchg,
        exchange_per_superstep=dict(
            state=arrays.exchange_volume(),
            etr=arrays.etr_exchange_volume(),
        ),
    )


def hop_delivery_leg() -> dict:
    """Per-impl hop-delivery timings (the fused-kernel acceptance number).

    Times the exact step the impl axis swaps — gather source state → apply
    the temporal edge mask → segment-reduce by arrival — on a dedicated
    serving-scale graph, in static and bucket mode.  The helper asserts
    bit-identity between the two paths before timing, so the reported
    speedup can never come from a diverged kernel."""
    from repro.core import superstep as SS

    g = generate_ldbc(LdbcParams(n_persons=HOP_N_PERSONS,
                                 degree_dist="facebook", seed=2))
    out = dict(n_persons=HOP_N_PERSONS, n_buckets=HOP_N_BUCKETS)
    for mode, name in ((SS.MODE_STATIC, "static"), (SS.MODE_BUCKET, "bucket")):
        out[name] = hop_delivery_times(g, mode, n_buckets=HOP_N_BUCKETS)
    return out


def dynamic_leg() -> dict:
    """Secondary measurement on the dynamic graph (bucket mode): per-query
    compute carries a ×n_buckets state, so vmap amortises a smaller overhead
    fraction — reported, not enforced."""
    params = LdbcParams(n_persons=N_PERSONS, degree_dist="facebook",
                        dynamic=True, seed=2)
    g = generate_ldbc(params)
    wl = make_workload(g, n_per_template=N_PER_TEMPLATE, seed=SEED)
    server = GraniteServer(g, use_planner=True, budget_s=BUDGET_S)
    seq_s = sum(r.latency_ms for r in server.run_workload(wl)) / 1e3
    sched = BatchScheduler(g, use_planner=True, budget_s=BUDGET_S)
    sched.run(wl, warm=True)
    bat_s = sum(d.service_s for d in sched.last_dispatches)
    return dict(graph=graph_name(params), n_queries=len(wl),
                drain_seq_s=seq_s, drain_batched_s=bat_s,
                throughput_ratio=seq_s / max(bat_s, 1e-12))


def run(out_path: str = "BENCH_serving.json") -> dict:
    # the hop micro runs FIRST: it times a single kernel-vs-scatter step, so
    # it must not inherit the heap/caches the workload legs accumulate
    hop = hop_delivery_leg()
    params = LdbcParams(n_persons=N_PERSONS, degree_dist="facebook",
                        dynamic=False, seed=2)
    g = generate_ldbc(params)
    wl = make_workload(g, n_per_template=N_PER_TEMPLATE, seed=SEED)
    n = len(wl)
    print(f"# serving: {graph_name(params)} — {n} queries "
          f"({N_PER_TEMPLATE}/template), seed={SEED}", flush=True)

    # ---- sequential baseline (run_workload warms per instance first)
    server = GraniteServer(g, use_planner=True, budget_s=BUDGET_S)
    seq_recs = server.run_workload(wl)
    seq_ms = np.asarray([r.latency_ms for r in seq_recs])
    seq_drain_s = float(seq_ms.sum()) / 1e3
    seq_tput = n / max(seq_drain_s, 1e-12)

    # ---- batched drain through the scheduler (warm dispatches)
    sched = BatchScheduler(g, use_planner=True, budget_s=BUDGET_S)
    bat_res = sched.run(wl, warm=True)
    bat_drain_s = sum(d.service_s for d in sched.last_dispatches)
    bat_tput = n / max(bat_drain_s, 1e-12)
    for a, b in zip(seq_recs, bat_res):
        assert a.count == b.count, (a.template, a.count, b.count)
    ratio = bat_tput / seq_tput

    # ---- open-loop replay at a rate the sequential loop cannot sustain
    rate = 2.0 * seq_tput
    replay_sched = BatchScheduler(g, use_planner=True, budget_s=BUDGET_S,
                                  plan_cache=sched.plan_cache,
                                  exec_cache=sched.exec_cache)
    rep = replay_workload(replay_sched, wl, rate_qps=rate, seed=SEED,
                          budget_s=BUDGET_S, warm=True)
    seq_sim = sequential_replay_sim(
        poisson_arrivals(n, rate, np.random.default_rng(SEED)), seq_ms / 1e3)

    report = dict(
        graph=graph_name(params),
        scale=SCALE,
        seed=SEED,
        n_queries=n,
        budget_s=BUDGET_S,
        sequential=dict(
            drain_s=seq_drain_s,
            throughput_qps=seq_tput,
            latency_ms_p50=float(np.percentile(seq_ms, 50)),
            latency_ms_p95=float(np.percentile(seq_ms, 95)),
            latency_ms_p99=float(np.percentile(seq_ms, 99)),
            completion_rate=float(np.mean([r.ok for r in seq_recs])),
        ),
        batched=dict(
            drain_s=bat_drain_s,
            throughput_qps=bat_tput,
            n_dispatches=len(sched.last_dispatches),
            mean_batch=float(np.mean(
                [d.n_real for d in sched.last_dispatches])),
            caches=sched.cache_report(),
        ),
        throughput_ratio=ratio,
        replay=rep.as_dict(),
        replay_sequential_sim=seq_sim,
        partitioned=partitioned_leg(g, wl, seq_drain_s),
        dynamic_leg=dynamic_leg(),
        hop_delivery=hop,
    )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    # emit()'s value column is µs-per-call: per-query drain cost here
    emit("serving/drain_seq_us_per_query", seq_drain_s / n * 1e6, f"n={n}")
    emit("serving/drain_batched_us_per_query", bat_drain_s / n * 1e6,
         f"ratio={ratio:.2f}x;dispatches={len(sched.last_dispatches)}")
    emit("serving/replay_p95_us", rep.latency_ms_p95 * 1e3,
         f"rate={rate:.1f}qps;completion={rep.completion_rate:.3f};"
         f"seq_sim_p95_ms={seq_sim['latency_ms_p95']:.1f}")
    emit("serving/hop_delivery_bucket_us", hop["bucket"]["pallas_ms"] * 1e3,
         f"speedup={hop['bucket']['speedup']:.2f}x;"
         f"static_speedup={hop['static']['speedup']:.2f}x;"
         f"edges={hop['bucket']['edges']}")
    print(f"# batched drain throughput {bat_tput:.1f} qps vs sequential "
          f"{seq_tput:.1f} qps → {ratio:.2f}x", flush=True)
    print(f"# fused hop kernel: static {hop['static']['speedup']:.2f}x, "
          f"bucket {hop['bucket']['speedup']:.2f}x vs materialize+segment_sum",
          flush=True)
    print(f"# wrote {out_path}", flush=True)
    if os.environ.get("BENCH_ENFORCE") == "1":
        if ratio < 2.0:
            print(f"# FAIL: throughput ratio {ratio:.2f}x < 2x", flush=True)
            sys.exit(1)
        # the fused-kernel acceptance floor: a real measured hop-delivery
        # speedup on both legs (thresholds leave slack for host jitter;
        # typical measured values are ~3-6x static, ~1.5-1.8x bucket)
        if hop["static"]["speedup"] < 1.5 or hop["bucket"]["speedup"] < 1.1:
            print(f"# FAIL: fused hop speedup static "
                  f"{hop['static']['speedup']:.2f}x (<1.5) or bucket "
                  f"{hop['bucket']['speedup']:.2f}x (<1.1)", flush=True)
            sys.exit(1)
    return report


def main():
    run()


if __name__ == "__main__":
    main()
