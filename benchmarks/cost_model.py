"""Fig 8/9 + Table 6 analogue: cost-model plan-choice quality.

For each query: execute EVERY split plan, find the optimal by measured time,
compare the model's choice; report %optimal / %2nd-best / %other and the
excess-time-over-optimal percentiles per template.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine as E
from repro.core.planner import Planner
from repro.core.stats import GraphStats
from repro.graphdata.ldbc import graph_name
from repro.graphdata.queries import make_workload

from .common import N_QUERIES, bench_graphs, emit, get_graph


def _measure(g, qry, split, repeat=3):
    E.count_results(g, qry, split=split)  # warm/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        E.count_results(g, qry, split=split)
    return (time.perf_counter() - t0) / repeat * 1e3


def run():
    for params in bench_graphs(dynamic_too=False):
        g = get_graph(params)
        name = graph_name(params)
        stats = GraphStats(g)
        planner = Planner(g, stats)
        wl = make_workload(g, n_per_template=N_QUERIES, seed=22)
        picked_rank = []
        excess = {}
        by_template_excess = {}
        for inst in wl:
            times = {s: _measure(g, inst.qry, s)
                     for s in range(inst.qry.n_vertices)}
            order = sorted(times, key=times.get)
            chosen = planner.choose(inst.qry).split
            picked_rank.append(order.index(chosen))
            exc = (times[chosen] - times[order[0]]) / max(times[order[0]], 1e-9)
            by_template_excess.setdefault(inst.template, []).append(exc * 100)
        ranks = np.asarray(picked_rank)
        emit(f"cost_model/{name}/plan_choice", 0.0,
             f"optimal={np.mean(ranks == 0)*100:.0f}%;"
             f"second={np.mean(ranks == 1)*100:.0f}%;"
             f"other={np.mean(ranks >= 2)*100:.0f}%")
        for t, ex in sorted(by_template_excess.items()):
            ex = np.asarray(ex)
            emit(f"cost_model/{name}/excess/{t}", 0.0,
                 f"p50={np.percentile(ex,50):.1f}%;p90={np.percentile(ex,90):.1f}%;"
                 f"p95={np.percentile(ex,95):.1f}%")


def main():
    run()


if __name__ == "__main__":
    main()
