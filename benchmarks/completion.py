"""Table 7 analogue: % of workload completing within the execution budget.

Granite-JAX vs the single-threaded Python baseline engine; the budget scales
the paper's 600 s to bench size.
"""
from __future__ import annotations

import time

from repro.core import engine as E
from repro.core.ref_engine import RefEngine
from repro.graphdata.ldbc import graph_name
from repro.graphdata.queries import make_workload
from repro.launch.query import GraniteServer

from .common import N_QUERIES, bench_graphs, emit, get_graph

BUDGET_S = 5.0


def run():
    for params in bench_graphs():
        g = get_graph(params)
        name = graph_name(params)
        wl = make_workload(g, n_per_template=max(2, N_QUERIES // 2), seed=51)
        server = GraniteServer(g, budget_s=BUDGET_S)
        recs = server.run_workload(wl)
        g_done = sum(r.ok for r in recs)
        ref = RefEngine(g, max_expansions=2_000_000)
        b_done = 0
        n_base = 0
        for inst in wl[:: max(1, len(wl) // 8)]:
            n_base += 1
            t0 = time.perf_counter()
            try:
                ref.count(inst.qry, mode=E.MODE_STATIC)
                if time.perf_counter() - t0 <= BUDGET_S:
                    b_done += 1
            except RuntimeError:
                pass
        emit(f"completion/{name}", 0.0,
             f"granite={g_done}/{len(recs)};baseline={b_done}/{n_base}")


def main():
    run()


if __name__ == "__main__":
    main()
