"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_reports():
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_time(t):
    if t is None:
        return "-"
    return f"{t*1e3:.3f}ms" if t >= 1e-3 else f"{t*1e6:.1f}µs"


def run(mesh: str = "single"):
    recs = load_reports()
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                 f"SKIPPED:{r['reason']}")
            continue
        if r.get("status") != "ok":
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0, "ERROR")
            continue
        dom = r["bottleneck"]
        tmax = max(r["t_compute"], r["t_memory"], r["t_collective"])
        frac = (r["t_compute"] / tmax) if tmax else 0.0
        uf = r.get("useful_flops_frac")
        emit(
            f"roofline/{r['arch']}/{r['shape']}",
            tmax * 1e6,
            f"tc={fmt_time(r['t_compute'])};tm={fmt_time(r['t_memory'])};"
            f"tx={fmt_time(r['t_collective'])};bottleneck={dom};"
            f"compute_frac={frac*100:.0f}%"
            + (f";useful_flops={uf*100:.0f}%" if uf else ""),
        )


def markdown_table(mesh: str = "single") -> str:
    rows = ["| arch | shape | t_compute | t_memory | t_collective | bottleneck "
            "| MODEL/HLO flops | scan_scale |",
            "|---|---|---|---|---|---|---|---|"]
    for r in load_reports():
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped: {r['reason']} | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        uf = r.get("useful_flops_frac")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_time(r['t_compute'])} | "
            f"{fmt_time(r['t_memory'])} | {fmt_time(r['t_collective'])} | "
            f"{r['bottleneck']} | {uf*100:.0f}% |" if uf else
            f"| {r['arch']} | {r['shape']} | {fmt_time(r['t_compute'])} | "
            f"{fmt_time(r['t_memory'])} | {fmt_time(r['t_collective'])} | "
            f"{r['bottleneck']} | — |",
        )
        rows[-1] += f" {r.get('scan_scale', 1.0):.0f} |"
    return "\n".join(rows)


def main():
    run()


if __name__ == "__main__":
    main()
